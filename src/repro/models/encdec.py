"""Encoder-decoder stack (seamless-m4t): speech-encoder (stub frames) +
text decoder with cross-attention.

The audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, frames, d_model).  Positions are
sinusoidal-additive (rope_variant='none' for this arch).  Decode shapes
exercise the decoder with cached self-attention KV and static cross
KV computed once at prefill.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.transformer import (
    attn_apply, attn_pspecs, build_positions, cross_attn_apply,
    dp_axes_of, embed_tokens, encode_cross_kv, ffn_apply,
    init_attn_params, init_embed_params, lm_head, maybe_shard, _dtype,
)


def sinusoidal(seq: int, d: int, offset=0) -> jax.Array:
    pos = (jnp.arange(seq, dtype=jnp.float32) + offset)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    out = jnp.zeros((seq, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang[:, : (d + 1) // 2]))
    return out


def init_encdec_params(cfg: ArchConfig, key) -> dict:
    dtype = _dtype(cfg)
    k_emb, k_enc, k_dec = jax.random.split(key, 3)
    params = init_embed_params(cfg, k_emb, dtype)
    params["enc_layers"] = jax.vmap(
        lambda kk: init_attn_params(cfg, kk, dtype))(
        jax.random.split(k_enc, cfg.enc_layers))
    params["dec_layers"] = jax.vmap(
        lambda kk: init_attn_params(cfg, kk, dtype, cross=True))(
        jax.random.split(k_dec, cfg.n_layers))
    params["enc_norm"] = jnp.ones((cfg.d_model,), dtype)
    return params


def encode(params, frames: jax.Array, cfg: ArchConfig,
           mesh: Optional[Mesh] = None) -> jax.Array:
    """frames (B, F, d) stub embeddings → encoder output (B, F, d)."""
    b, f, d = frames.shape
    x = frames.astype(_dtype(cfg)) + sinusoidal(f, d).astype(
        _dtype(cfg))[None]
    x = maybe_shard(x, mesh, dp_axes_of(mesh), None, None)
    positions = build_positions(cfg, b, f)

    def body(xc, lp):
        xc, _ = attn_apply(lp, xc, cfg=cfg, mesh=mesh,
                           positions=positions, mode="train",
                           causal=False)
        xc = ffn_apply(lp, xc, cfg, mesh)
        return xc, None

    wrapped = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, _ = jax.lax.scan(wrapped, x, params["enc_layers"])
    else:
        for i in range(cfg.enc_layers):
            lp = jax.tree.map(lambda p: p[i], params["enc_layers"])
            x, _ = wrapped(x, lp)
    from repro.models.layers import rmsnorm
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _dec_layer(lp, x, enc_kv, *, cfg, mesh, positions, mode,
               cache=None, cache_len=None):
    x, new_kv = attn_apply(lp, x, cfg=cfg, mesh=mesh, positions=positions,
                           mode=mode, cache=cache, cache_len=cache_len)
    x = cross_attn_apply(lp, x, enc_kv, cfg, mesh)
    x = ffn_apply(lp, x, cfg, mesh)
    return x, new_kv


def _embed_dec(params, tokens, cfg, mesh, offset=0):
    x = embed_tokens(params, tokens, cfg, mesh)
    pe = sinusoidal(tokens.shape[1], cfg.d_model, offset=offset)
    return x + pe.astype(x.dtype)[None]


def forward_train(params, tokens, frames, cfg: ArchConfig,
                  mesh: Optional[Mesh] = None) -> jax.Array:
    """Teacher-forced decoder logits (B, S, V)."""
    enc_out = encode(params, frames, cfg, mesh)
    b, s = tokens.shape
    x = _embed_dec(params, tokens, cfg, mesh)
    positions = build_positions(cfg, b, s)

    def body(xc, lp):
        enc_kv = encode_cross_kv(lp, enc_out, cfg)
        xc, _ = _dec_layer(lp, xc, enc_kv, cfg=cfg, mesh=mesh,
                           positions=positions, mode="train")
        return xc, None

    wrapped = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, _ = jax.lax.scan(wrapped, x, params["dec_layers"])
    else:
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda p: p[i], params["dec_layers"])
            x, _ = wrapped(x, lp)
    return lm_head(params, x, cfg, mesh)


def prefill(params, tokens, frames, cfg: ArchConfig,
            mesh: Optional[Mesh] = None):
    """Returns (last logits, cache={self{k,v}, cross{k,v}})."""
    enc_out = encode(params, frames, cfg, mesh)
    b, s = tokens.shape
    x = _embed_dec(params, tokens, cfg, mesh)
    positions = build_positions(cfg, b, s)

    def body(xc, lp):
        enc_kv = encode_cross_kv(lp, enc_out, cfg)
        xc, kv = _dec_layer(lp, xc, enc_kv, cfg=cfg, mesh=mesh,
                            positions=positions, mode="prefill")
        return xc, (kv, enc_kv)

    wrapped = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, (self_kv, cross_kv) = jax.lax.scan(wrapped, x,
                                              params["dec_layers"])
    else:
        ys = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda p_: p_[i], params["dec_layers"])
            x, y = wrapped(x, lp)
            ys.append(y)
        self_kv, cross_kv = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    logits = lm_head(params, x[:, -1:], cfg, mesh)[:, 0]
    return logits, {"self": self_kv, "cross": cross_kv}


def decode_step(params, token, cache, cache_len, cfg: ArchConfig,
                mesh: Optional[Mesh] = None):
    b = token.shape[0]
    x = _embed_dec(params, token, cfg, mesh, offset=cache_len)
    positions = build_positions(cfg, b, 1, offset=cache_len)

    def body(xc, inp):
        lp, self_kv, cross_kv = inp
        xc, new_kv = _dec_layer(lp, xc, cross_kv, cfg=cfg, mesh=mesh,
                                positions=positions, mode="decode",
                                cache=self_kv, cache_len=cache_len)
        return xc, new_kv

    if cfg.scan_layers:
        x, new_self = jax.lax.scan(
            body, x,
            (params["dec_layers"], cache["self"], cache["cross"]))
    else:
        ys = []
        for i in range(cfg.n_layers):
            inp = jax.tree.map(
                lambda p_: p_[i],
                (params["dec_layers"], cache["self"], cache["cross"]))
            x, y = body(x, inp)
            ys.append(y)
        new_self = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    logits = lm_head(params, x, cfg, mesh)[:, 0]
    return logits, {"self": new_self, "cross": cache["cross"]}


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    dtype = _dtype(cfg)
    self_shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                  cfg.head_dim)
    cross_shape = (cfg.n_layers, batch, cfg.frontend_len,
                   cfg.n_kv_heads, cfg.head_dim)
    return {
        "self": {"k": jnp.zeros(self_shape, dtype),
                 "v": jnp.zeros(self_shape, dtype)},
        "cross": {"k": jnp.zeros(cross_shape, dtype),
                  "v": jnp.zeros(cross_shape, dtype)},
    }


def encdec_param_pspecs(cfg: ArchConfig, mesh: Mesh) -> dict:
    dp = dp_axes_of(mesh) or None
    return {
        "embed": ({"hash_tables": P(None, None, "model")}
                  if cfg.embedding == "bbit_hash"
                  else {"table": P(None, "model")}),
        "final_norm": P(None),
        "enc_norm": P(None),
        "lm_head": P(dp, "model"),
        "enc_layers": attn_pspecs(cfg, dp, stacked=True),
        "dec_layers": attn_pspecs(cfg, dp, stacked=True, cross=True),
    }
