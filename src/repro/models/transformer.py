"""Unified decoder-LM covering the dense / MoE / hybrid / ssm / vlm
families, with train, prefill, and decode entry points.

Execution modes:
  * ``forward_train``  — full-sequence causal logits (train_4k cells).
  * ``prefill``        — causal pass returning last-position logits +
                         cache (prefill_32k cells).
  * ``decode_step``    — one token against a cache (decode_* cells).

Distribution: pjit auto-sharding steered by ``param_pspecs`` (TP over
'model', FSDP over the data axes) + ``maybe_shard`` activation
constraints; MoE uses an explicit ``shard_map`` EP dispatch
(models/moe.py).  ``scan_layers`` keeps the full-step HLO compact for
the multi-pod compile; per-layer cost probes (launch/roofline.py)
recover accurate FLOP counts (XLA cost analysis counts while-loop
bodies once — measured, see DESIGN.md).

Attention is blockwise with trace-time causal skipping, so compiled
attention FLOPs track the triangular optimum.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import (
    apply_rope, blockwise_attention, rmsnorm, swiglu,
    hashed_embed_params, hashed_embed_lookup,
)


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------
def dp_axes_of(mesh: Optional[Mesh]) -> Tuple[str, ...]:
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def maybe_shard(x: jax.Array, mesh: Optional[Mesh], *spec) -> jax.Array:
    """with_sharding_constraint, skipping non-divisible dims."""
    if mesh is None:
        return x
    fixed = []
    for dim, s in zip(x.shape, spec):
        if s is None:
            fixed.append(None)
            continue
        axes = (s,) if isinstance(s, str) else tuple(s)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        fixed.append(s if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def remat_wrap(cfg: ArchConfig, fn):
    """jax.checkpoint with the config's policy ('dots' saves matmul
    outputs — recompute only elementwise chains in backward)."""
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# attention + mlp blocks
# ---------------------------------------------------------------------------
def init_attn_params(cfg: ArchConfig, key, dtype, with_ffn: bool = True,
                     cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 10)
    sc = d ** -0.5
    p = {
        "ln1": jnp.ones((d,), dtype),
        "wq": (jax.random.normal(ks[0], (d, h * hd)) * sc).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, kv * hd)) * sc).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, kv * hd)) * sc).astype(dtype),
        "wo": (jax.random.normal(ks[3], (h * hd, d))
               * (h * hd) ** -0.5).astype(dtype),
    }
    if cross:
        p.update({
            "ln_x": jnp.ones((d,), dtype),
            "xq": (jax.random.normal(ks[4], (d, h * hd)) * sc).astype(dtype),
            "xk": (jax.random.normal(ks[5], (d, kv * hd)) * sc).astype(dtype),
            "xv": (jax.random.normal(ks[6], (d, kv * hd)) * sc).astype(dtype),
            "xo": (jax.random.normal(ks[7], (h * hd, d))
                   * (h * hd) ** -0.5).astype(dtype),
        })
    if with_ffn:
        p["ln2"] = jnp.ones((d,), dtype)
        if cfg.is_moe and not cross:
            p["moe"] = moe_lib.init_moe_params(cfg, ks[8], dtype)
        else:
            f = cfg.d_ff
            kf = jax.random.split(ks[8], 3)
            p["mlp"] = {
                "w_gate": (jax.random.normal(kf[0], (d, f)) * sc
                           ).astype(dtype),
                "w_up": (jax.random.normal(kf[1], (d, f)) * sc).astype(dtype),
                "w_down": (jax.random.normal(kf[2], (f, d)) * f ** -0.5
                           ).astype(dtype),
            }
    return p


def _project_qkv(lp, h, cfg: ArchConfig, mesh, prefix=""):
    b, s, d = h.shape
    hd = cfg.head_dim
    wq, wk, wv = lp[prefix + ("q" if prefix else "wq")], \
        lp[prefix + ("k" if prefix else "wk")], \
        lp[prefix + ("v" if prefix else "wv")]
    q = (h @ wq).reshape(b, s, cfg.n_heads, hd)
    k = (h @ wk).reshape(b, s, cfg.n_kv_heads, hd)
    v = (h @ wv).reshape(b, s, cfg.n_kv_heads, hd)
    dp = dp_axes_of(mesh)
    q = maybe_shard(q, mesh, dp, None, "model", None)
    k = maybe_shard(k, mesh, dp, None, "model", None)
    v = maybe_shard(v, mesh, dp, None, "model", None)
    return q, k, v


def _pad_heads_for_tp(q, k, v, cfg: ArchConfig, mesh):
    """Group-aware head padding so attention shards over 'model'.

    When n_heads doesn't divide the model axis (granite 24H, qwen 12H on
    16-way TP) attention silently runs replicated per device.  Exact
    fix: replicate each kv head r = model/kv times and pad each q-group
    from g to ceil(g/r) per kv-replica (zero rows, sliced off after).
    Returns (q', k', v', orig_heads_per_group g, padded group g_new, r).
    """
    mdl = mesh.shape.get("model", 1)
    h, kv = cfg.n_heads, cfg.n_kv_heads
    if h % mdl == 0 or mdl % kv != 0:
        return q, k, v, None
    r = mdl // kv
    g = h // kv
    g_new = -(-g // r)                 # ceil
    b, s, _, hd = q.shape
    qg = q.reshape(b, s, kv, g, hd)
    pad = r * g_new - g
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    q = qg.reshape(b, s, kv * r * g_new, hd)
    k = jnp.repeat(k, r, axis=2)
    v = jnp.repeat(v, r, axis=2)
    from repro.models.transformer import maybe_shard as _ms
    dp = dp_axes_of(mesh)
    q = _ms(q, mesh, dp, None, "model", None)
    k = _ms(k, mesh, dp, None, "model", None)
    v = _ms(v, mesh, dp, None, "model", None)
    return q, k, v, (g, g_new, r)


def _unpad_heads(out, pad_info, cfg: ArchConfig):
    if pad_info is None:
        return out
    g, g_new, r = pad_info
    b, s, hp, hd = out.shape
    og = out.reshape(b, s, cfg.n_kv_heads, r * g_new, hd)[:, :, :, :g]
    return og.reshape(b, s, cfg.n_heads, hd)


def attn_apply(
    lp: dict,
    x: jax.Array,
    *,
    cfg: ArchConfig,
    mesh: Optional[Mesh],
    positions: jax.Array,
    mode: str = "train",              # train | prefill | decode
    cache: Optional[dict] = None,     # {k,v} (B,Smax,KV,hd) for decode
    cache_len=None,
    causal: bool = True,
):
    """Self-attention block.  Returns (x', new_cache_or_None)."""
    b, s, d = x.shape
    h_in = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = _project_qkv(lp, h_in, cfg, mesh)
    q, k = apply_rope(q, k, positions, variant=cfg.rope_variant,
                      theta=cfg.rope_theta,
                      mrope_sections=cfg.mrope_sections)
    pad_info = None
    if cfg.attn_pad_heads and mesh is not None and mode == "train":
        q, k, v, pad_info = _pad_heads_for_tp(q, k, v, cfg, mesh)
    if mode != "train" and cfg.kv_repeat_to > cfg.n_kv_heads:
        # exact GQA transform: duplicating each KV head r× (and
        # re-grouping q) lets prefill/decode caches shard over 'model'
        # instead of the sequence dim (§Perf: removes per-layer psum
        # softmax merges + resharding copies in decode)
        r = cfg.kv_repeat_to // cfg.n_kv_heads
        k = jnp.repeat(k, r, axis=2)
        v = jnp.repeat(v, r, axis=2)
        k = maybe_shard(k, mesh, dp_axes_of(mesh), None, "model", None)
        v = maybe_shard(v, mesh, dp_axes_of(mesh), None, "model", None)
    new_cache = None
    if mode == "decode":
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache_len, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache_len, axis=1)
        out = blockwise_attention(
            q, ck, cv, causal=False, kv_valid_len=cache_len + s,
            q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
            impl=cfg.attn_impl)
        new_cache = {"k": ck, "v": cv}
    else:
        out = blockwise_attention(
            q, k, v, causal=causal,
            q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
            impl=cfg.attn_impl)
        if mode == "prefill":
            new_cache = {"k": k, "v": v}
    out = _unpad_heads(out, pad_info, cfg)
    y = out.reshape(b, s, cfg.n_heads * cfg.head_dim) @ lp["wo"]
    y = maybe_shard(y, mesh, dp_axes_of(mesh), None, None)
    return x + y, new_cache


def cross_attn_apply(lp, x, enc_kv, cfg: ArchConfig, mesh):
    """Cross-attention with precomputed encoder K/V {k,v}."""
    b, s, d = x.shape
    h_in = rmsnorm(x, lp["ln_x"], cfg.norm_eps)
    hd = cfg.head_dim
    q = (h_in @ lp["xq"]).reshape(b, s, cfg.n_heads, hd)
    out = blockwise_attention(
        q, enc_kv["k"], enc_kv["v"], causal=False,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
        impl=cfg.attn_impl)
    y = out.reshape(b, s, cfg.n_heads * hd) @ lp["xo"]
    return x + y


def encode_cross_kv(lp, enc_out, cfg: ArchConfig):
    b, f, d = enc_out.shape
    hd = cfg.head_dim
    k = (enc_out @ lp["xk"]).reshape(b, f, cfg.n_kv_heads, hd)
    v = (enc_out @ lp["xv"]).reshape(b, f, cfg.n_kv_heads, hd)
    return {"k": k, "v": v}


def ffn_apply(lp, x, cfg: ArchConfig, mesh, serving: bool = False):
    h_in = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        y = moe_lib.moe_ffn(h_in, lp["moe"], cfg, mesh, serving=serving)
    else:
        m = lp["mlp"]
        hidden = jax.nn.silu(h_in @ m["w_gate"]) * (h_in @ m["w_up"])
        hidden = maybe_shard(hidden, mesh, dp_axes_of(mesh), None, "model")
        y = hidden @ m["w_down"]
    y = maybe_shard(y, mesh, dp_axes_of(mesh), None, None)
    return x + y


def dense_layer_apply(lp, x, *, cfg, mesh, positions, mode="train",
                      cache=None, cache_len=None, causal=True):
    x, new_cache = attn_apply(lp, x, cfg=cfg, mesh=mesh,
                              positions=positions, mode=mode, cache=cache,
                              cache_len=cache_len, causal=causal)
    x = ffn_apply(lp, x, cfg, mesh, serving=(mode != "train"))
    return x, new_cache


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------
def init_embed_params(cfg: ArchConfig, key, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    if cfg.embedding == "bbit_hash":
        emb = hashed_embed_params(cfg.vocab, cfg.d_model, cfg.hash_k,
                                  cfg.hash_b, k1, dtype)
    else:
        emb = {"table": (jax.random.normal(
            k1, (cfg.vocab, cfg.d_model)) * 0.02).astype(dtype)}
    return {
        "embed": emb,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": (jax.random.normal(k2, (cfg.d_model, cfg.vocab))
                    * cfg.d_model ** -0.5).astype(dtype),
    }


def embed_tokens(params, tokens, cfg: ArchConfig, mesh):
    # XLA SPMD workaround (verified on jax 0.8.2): a gather whose operand
    # is 'model'-sharded AND whose indices are data-sharded inside a
    # grad-accumulation loop trips an invalid dynamic-slice after
    # partitioning.  Token ids are tiny — replicate them for the gather;
    # the output constraint re-shards the embeddings immediately after.
    if mesh is not None:
        tokens = jax.lax.with_sharding_constraint(
            tokens, NamedSharding(mesh, P(*([None] * tokens.ndim))))
    if cfg.embedding == "bbit_hash":
        x = hashed_embed_lookup(params["embed"], tokens, cfg.hash_k,
                                cfg.hash_b)
    else:
        x = params["embed"]["table"][tokens]
    return maybe_shard(x, mesh, dp_axes_of(mesh), None, None)


def lm_head(params, x, cfg: ArchConfig, mesh):
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    return maybe_shard(logits, mesh, dp_axes_of(mesh), None, "model")


def xent_loss(logits, targets):
    """Mean CE; logits (B,S,V) any dtype, targets (B,S) int32.

    The gold logit is extracted with a one-hot contraction, not
    ``take_along_axis`` — a gather along the 'model'-sharded vocab dim
    makes XLA all-gather the full-V f32 logits (measured: 2.7 GiB per
    microbatch on kimi-k2), while the one-hot einsum stays sharded.
    """
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(targets.astype(jnp.int32), logits.shape[-1],
                            dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", lf, onehot.astype(jnp.float32))
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# positions (standard / mrope-with-vision-prefix)
# ---------------------------------------------------------------------------
def build_positions(cfg: ArchConfig, batch: int, seq: int, offset=0):
    """Absolute positions; ``offset`` is the first token's index (decode)."""
    idx = jnp.arange(seq, dtype=jnp.int32) + offset     # absolute ids
    if cfg.rope_variant != "mrope":
        return jnp.broadcast_to(idx[None, :], (batch, seq))
    # M-RoPE: the first frontend_len absolute positions are a patch grid
    # (t=0, h, w); text continues with equal (t,h,w) ids after it.
    n_vis = cfg.frontend_len if cfg.frontend == "vision_stub" else 0
    side = max(int(n_vis ** 0.5), 1)
    t_pos = jnp.where(idx < n_vis, 0, idx - n_vis + 1)
    h_pos = jnp.where(idx < n_vis, idx // side, idx - n_vis + 1)
    w_pos = jnp.where(idx < n_vis, idx % side, idx - n_vis + 1)
    pos3 = jnp.stack([t_pos, h_pos, w_pos], axis=-1)[None]
    return jnp.broadcast_to(pos3, (batch, seq, 3))


# ---------------------------------------------------------------------------
# the decoder-only families: dense / moe / vlm
# ---------------------------------------------------------------------------
def init_decoder_params(cfg: ArchConfig, key) -> dict:
    dtype = _dtype(cfg)
    k_emb, k_layers = jax.random.split(key)
    params = init_embed_params(cfg, k_emb, dtype)
    keys = jax.random.split(k_layers, cfg.n_layers)
    params["layers"] = jax.vmap(
        lambda kk: init_attn_params(cfg, kk, dtype))(keys)
    return params


def _scan_layers(params, x, body, cfg: ArchConfig, ys_in=None):
    """Runs ``body`` over the stacked layer params (scan or unrolled)."""
    if cfg.scan_layers:
        wrapped = remat_wrap(cfg, body)
        x, ys = jax.lax.scan(wrapped, x,
                             (params["layers"], ys_in)
                             if ys_in is not None else params["layers"])
        return x, ys
    ys_out = []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda p: p[i], params["layers"])
        yin = None if ys_in is None else jax.tree.map(
            lambda p: p[i], ys_in)
        fn = remat_wrap(cfg, body)
        x, y = fn(x, (lp, yin) if ys_in is not None else lp)
        ys_out.append(y)
    ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys_out) \
        if ys_out and ys_out[0] is not None else None
    return x, ys


def forward_train(params, tokens, cfg: ArchConfig,
                  mesh: Optional[Mesh] = None,
                  vision_embeds: Optional[jax.Array] = None) -> jax.Array:
    """tokens (B,S) → logits (B,S,V)."""
    b, s = tokens.shape
    x = embed_tokens(params, tokens, cfg, mesh)
    if vision_embeds is not None and cfg.frontend == "vision_stub":
        n_vis = vision_embeds.shape[1]
        x = jnp.concatenate(
            [vision_embeds.astype(x.dtype), x[:, n_vis:]], axis=1)
    positions = build_positions(cfg, b, s)

    def body(xc, lp):
        xc, _ = dense_layer_apply(lp, xc, cfg=cfg, mesh=mesh,
                                  positions=positions, mode="train")
        return xc, None

    x, _ = _scan_layers(params, x, body, cfg)
    return lm_head(params, x, cfg, mesh)


def prefill(params, tokens, cfg: ArchConfig,
            mesh: Optional[Mesh] = None,
            vision_embeds: Optional[jax.Array] = None):
    """Returns (last-position logits (B,V), cache pytree)."""
    b, s = tokens.shape
    x = embed_tokens(params, tokens, cfg, mesh)
    if vision_embeds is not None and cfg.frontend == "vision_stub":
        n_vis = vision_embeds.shape[1]
        x = jnp.concatenate(
            [vision_embeds.astype(x.dtype), x[:, n_vis:]], axis=1)
    positions = build_positions(cfg, b, s)

    def body(xc, lp):
        xc, kv = dense_layer_apply(lp, xc, cfg=cfg, mesh=mesh,
                                   positions=positions, mode="prefill")
        return xc, kv

    x, cache = _scan_layers(params, x, body, cfg)
    logits = lm_head(params, x[:, -1:], cfg, mesh)[:, 0]
    return logits, cache


def decode_step(params, token, cache, cache_len, cfg: ArchConfig,
                mesh: Optional[Mesh] = None):
    """token (B,1) against cache {k,v} (L,B,Smax,KV,hd).

    Returns (logits (B,V), updated cache).
    """
    b = token.shape[0]
    x = embed_tokens(params, token, cfg, mesh)
    positions = build_positions(cfg, b, 1, offset=cache_len)

    def body(xc, lp_cache):
        lp, cache_l = lp_cache
        xc, new_kv = dense_layer_apply(
            lp, xc, cfg=cfg, mesh=mesh, positions=positions,
            mode="decode", cache=cache_l, cache_len=cache_len)
        return xc, new_kv

    x, new_cache = _scan_layers(params, x, body, cfg, ys_in=cache)
    logits = lm_head(params, x, cfg, mesh)[:, 0]
    return logits, new_cache


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=None) -> dict:
    dtype = dtype or _dtype(cfg)
    kv = max(cfg.n_kv_heads, cfg.kv_repeat_to or 0)
    shape = (cfg.n_layers, batch, max_len, kv, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# parameter PartitionSpecs (TP over 'model', FSDP over data axes)
# ---------------------------------------------------------------------------
def attn_pspecs(cfg: ArchConfig, dp, stacked: bool = True,
                cross: bool = False) -> dict:
    lead = (None,) if stacked else ()
    mk = lambda *spec: P(*(lead + spec))
    p = {
        "ln1": mk(None),
        "wq": mk(dp, "model"),
        "wk": mk(dp, "model"),
        "wv": mk(dp, "model"),
        "wo": mk("model", dp),
    }
    if cross:
        p.update({"ln_x": mk(None), "xq": mk(dp, "model"),
                  "xk": mk(dp, "model"), "xv": mk(dp, "model"),
                  "xo": mk("model", dp)})
    p["ln2"] = mk(None)
    if cfg.is_moe and not cross:
        mp = moe_lib.moe_param_pspecs(cfg, dp_axes=dp if dp else ())
        p["moe"] = jax.tree.map(
            lambda s: P(*(lead + tuple(s))), mp,
            is_leaf=lambda s: isinstance(s, P))
    else:
        p["mlp"] = {"w_gate": mk(dp, "model"), "w_up": mk(dp, "model"),
                    "w_down": mk("model", dp)}
    return p


def decoder_param_pspecs(cfg: ArchConfig, mesh: Mesh) -> dict:
    dp = dp_axes_of(mesh) or None
    emb = ({"hash_tables": P(None, None, "model")}
           if cfg.embedding == "bbit_hash"
           else {"table": P(None, "model")})
    return {
        "embed": emb,
        "final_norm": P(None),
        "lm_head": P(dp, "model"),
        "layers": attn_pspecs(cfg, dp, stacked=cfg.scan_layers or True),
    }
