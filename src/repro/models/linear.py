"""Linear models over b-bit minwise-hashed codes (paper §3).

The weight lives as a (k, 2^b, C) table — the expanded 2^b·k weight
vector reshaped — and the forward pass is the fused Pallas kernel
(one-hot MXU contraction) or an XLA gather; both equal the paper's
explicit-expansion dot product (unit-tested).

Also provides ``VWLinear`` (dense linear over VW sketches) so the
paper's §5 comparison trains both methods through identical machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro import perf
from repro.kernels import ops, ref


@dataclasses.dataclass(frozen=True)
class BBitLinearConfig:
    k: int
    b: int
    n_classes: int = 2
    # 'auto' → Pallas kernel on TPU, XLA gather elsewhere (interpret-mode
    # Pallas would crawl on CPU); 'always'/'never' force either path.
    use_kernel: str = "auto"
    param_dtype: str = "float32"
    normalize: bool = False      # optional 1/sqrt(k) feature scaling

    @property
    def n_out(self) -> int:
        return 1 if self.n_classes == 2 else self.n_classes

    @property
    def n_weights(self) -> int:
        return self.k * (1 << self.b) * self.n_out + self.n_out


def init_bbit_linear(cfg: BBitLinearConfig, key: Optional[jax.Array] = None):
    dtype = jnp.dtype(cfg.param_dtype)
    table = jnp.zeros((cfg.k, 1 << cfg.b, cfg.n_out), dtype)
    bias = jnp.zeros((cfg.n_out,), dtype)
    if key is not None:
        table = 0.01 * jax.random.normal(key, table.shape, dtype)
    return {"table": table, "bias": bias}


def _forced_impl(cfg: BBitLinearConfig, kernel: str, fallback: str
                 ) -> Optional[str]:
    """Map the config's ``use_kernel`` tri-state onto a perf pin:
    'always'→kernel, 'never'→the fallback arm, 'auto'→None (let
    ``perf.choose`` decide — static TPU heuristic unless a measured
    profile says otherwise)."""
    if cfg.use_kernel == "always" or cfg.use_kernel is True:
        return kernel
    if cfg.use_kernel == "never" or cfg.use_kernel is False:
        return fallback
    return None


def logits_impl(cfg: BBitLinearConfig, rows: Optional[int] = None) -> str:
    """The widened-codes dispatch choice: 'kernel' | 'gather'."""
    shape = {"k": cfg.k, "b": cfg.b, "v": 1 << cfg.b}
    if rows is not None:
        shape["rows"] = int(rows)
    return perf.choose("logits", shape,
                       impl=_forced_impl(cfg, "kernel", "gather"))


def logits_packed_impl(cfg: BBitLinearConfig,
                       rows: Optional[int] = None) -> str:
    """The packed-rows dispatch choice: 'kernel' | 'unpack'."""
    shape = {"k": cfg.k, "b": cfg.b, "v": 1 << cfg.b}
    if rows is not None:
        shape["rows"] = int(rows)
    return perf.choose("logits_packed", shape,
                       impl=_forced_impl(cfg, "kernel", "unpack"))


def bbit_logits(params, codes: jax.Array, cfg: BBitLinearConfig,
                empty: Optional[jax.Array] = None):
    """codes uint16/int32 (n, k) → logits (n, n_out) float32.

    ``empty`` (bool (n, k), zero-coded OPH only) drops the marked bins'
    contributions — the all-zero one-hot block of arXiv:1208.1259 §6.
    """
    if empty is not None:
        gathered = jnp.take_along_axis(
            params["table"][None],
            codes.astype(jnp.int32)[:, :, None, None],
            axis=2,
        )[:, :, 0, :].astype(jnp.float32)
        out = jnp.where(empty[:, :, None], 0.0, gathered).sum(axis=1)
    elif logits_impl(cfg, rows=codes.shape[0]) == "kernel":
        out = ops.bbit_linear(codes.astype(jnp.int32), params["table"])
    else:
        out = ref.bbit_linear_fwd(codes, params["table"])
    if cfg.normalize:
        out = out / jnp.sqrt(jnp.float32(cfg.k))
    return out + params["bias"].astype(jnp.float32)


def bbit_logits_packed(params, packed: jax.Array, cfg: BBitLinearConfig,
                       empty_packed: Optional[jax.Array] = None):
    """Packed uint8 (n, ceil(k·b/8)) rows → logits (n, n_out) float32.

    The streaming trainer's forward: minibatches arrive in the on-disk
    packed layout and stay packed.  On the kernel path (TPU, byte-
    aligned b, 2^b within the table-stream bound) the Pallas kernels
    unpack b-bit codes in-register, so the (n, k) int32 code matrix of
    the old ``unpack_codes_jnp`` + ``bbit_logits`` two-step never
    materializes — and ``empty_packed`` (the ``oph_zero`` bitmask,
    np.packbits layout) is fused into the same pass instead of forcing
    the XLA gather.  Elsewhere it lowers to exactly that two-step
    inside the caller's jit (bit-identical numerics; the widened codes
    are a fused temporary).
    """
    if logits_packed_impl(cfg, rows=packed.shape[0]) == "kernel":
        out = ops.bbit_linear_packed(packed, params["table"], cfg.k,
                                     cfg.b, empty=empty_packed)
        if cfg.normalize:
            out = out / jnp.sqrt(jnp.float32(cfg.k))
        return out + params["bias"].astype(jnp.float32)
    from repro.core.bbit import unpack_codes_jnp, unpack_mask_jnp
    codes = unpack_codes_jnp(packed, cfg.k, cfg.b).astype(jnp.int32)
    empty = (unpack_mask_jnp(empty_packed, cfg.k)
             if empty_packed is not None else None)
    return bbit_logits(params, codes, cfg, empty=empty)


def bbit_scores(params, codes: jax.Array, cfg: BBitLinearConfig,
                empty: Optional[jax.Array] = None) -> jax.Array:
    """Serving-shaped scores: binary → (n,) margin, multiclass →
    (n, C) logits — the value a classifier service returns per row."""
    logits = bbit_logits(params, codes, cfg, empty=empty)
    return logits[:, 0] if cfg.n_classes == 2 else logits


def bbit_scores_packed(params, packed: jax.Array, cfg: BBitLinearConfig,
                       empty_packed: Optional[jax.Array] = None
                       ) -> jax.Array:
    """``bbit_scores`` straight off packed uint8 rows (see
    ``bbit_logits_packed``) — the fused serving hot path's back half."""
    logits = bbit_logits_packed(params, packed, cfg,
                                empty_packed=empty_packed)
    return logits[:, 0] if cfg.n_classes == 2 else logits


def predict_classes(params, codes, cfg: BBitLinearConfig) -> jax.Array:
    logits = bbit_logits(params, codes, cfg)
    if cfg.n_classes == 2:
        return (logits[:, 0] > 0).astype(jnp.int32)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class VWLinearConfig:
    m: int                       # number of VW buckets
    n_classes: int = 2

    @property
    def n_out(self) -> int:
        return 1 if self.n_classes == 2 else self.n_classes


def init_vw_linear(cfg: VWLinearConfig, key: Optional[jax.Array] = None):
    w = jnp.zeros((cfg.m, cfg.n_out), jnp.float32)
    if key is not None:
        w = 0.01 * jax.random.normal(key, w.shape, jnp.float32)
    return {"w": w, "bias": jnp.zeros((cfg.n_out,), jnp.float32)}


def vw_logits(params, sketches: jax.Array, cfg: VWLinearConfig):
    return sketches @ params["w"] + params["bias"]


def vw_predict(params, sketches, cfg: VWLinearConfig) -> jax.Array:
    logits = vw_logits(params, sketches, cfg)
    if cfg.n_classes == 2:
        return (logits[:, 0] > 0).astype(jnp.int32)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
