"""Hybrid (zamba2) and xLSTM (xlstm-350m) model stacks.

zamba2: ``n_layers`` Mamba2 blocks; before every group of
``hybrid_attn_every`` blocks a *shared* attention(+MLP) block is
applied, alternating between ``hybrid_shared_attn_blocks`` weight sets
(Zamba weight sharing).  Layout (81L, every=6): 13 groups of
[shared-attn, 6×mamba] + 3 tail mamba blocks → 81 mamba blocks,
13 shared-attn applications.  (Simplification noted in DESIGN.md: the
original concatenates the initial embedding into the shared block's
input; we use the plain residual stream.)

xlstm: groups of [(slstm_every−1)×mLSTM, 1×sLSTM].

Both families expose the same train/prefill/decode contract as
models/transformer.py and are sub-quadratic → they serve long_500k.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.transformer import (
    attn_apply, attn_pspecs, build_positions, dp_axes_of,
    embed_tokens, init_attn_params, init_embed_params, lm_head,
    maybe_shard, _dtype,
)
from repro.models.layers import rmsnorm




def _loop(cfg, body, x, xs, length):
    """lax.scan when cfg.scan_layers else an unrolled python loop.

    ``xs`` is a pytree stacked on the leading axis (or None).  Returns
    (carry, stacked ys) like lax.scan.
    """
    if cfg.scan_layers:
        return jax.lax.scan(body, x, xs)
    ys = []
    for i in range(length):
        xi = jax.tree.map(lambda a: a[i], xs) if xs is not None else None
        x, y = body(x, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return x, ys

# ---------------------------------------------------------------------------
# zamba2-style hybrid
# ---------------------------------------------------------------------------
def _hybrid_layout(cfg: ArchConfig) -> Tuple[int, int, int]:
    per = cfg.hybrid_attn_every
    groups = cfg.n_layers // per
    tail = cfg.n_layers - groups * per
    return groups, per, tail


def init_hybrid_params(cfg: ArchConfig, key) -> dict:
    dtype = _dtype(cfg)
    groups, per, tail = _hybrid_layout(cfg)
    k_emb, k_m, k_t, k_a = jax.random.split(key, 4)

    def init_mamba_layer(kk):
        k1, k2 = jax.random.split(kk)
        p = ssm_lib.init_mamba2_params(cfg, k1, dtype)
        p["ln"] = jnp.ones((cfg.d_model,), dtype)
        return p

    params = init_embed_params(cfg, k_emb, dtype)
    if groups:
        km = jax.random.split(k_m, groups * per).reshape(groups, per)
        params["mamba"] = jax.vmap(jax.vmap(init_mamba_layer))(km)
    else:
        proto = jax.eval_shape(init_mamba_layer, jax.random.key(0))
        params["mamba"] = jax.tree.map(
            lambda sd: jnp.zeros((0, per) + sd.shape, sd.dtype), proto)
    if tail:
        params["mamba_tail"] = jax.vmap(init_mamba_layer)(
            jax.random.split(k_t, tail))
    ka = jax.random.split(k_a, cfg.hybrid_shared_attn_blocks)
    params["attn"] = jax.vmap(
        lambda kk: init_attn_params(cfg, kk, dtype))(ka)
    return params


def _mamba_block(lp, x, cfg, mesh, state=None, chunk=128):
    h = rmsnorm(x, lp["ln"], cfg.norm_eps)
    h = maybe_shard(h, mesh, dp_axes_of(mesh), None, None)
    y, new_state = ssm_lib.mamba2_forward(
        {k: v for k, v in lp.items() if k != "ln"}, h, cfg,
        h0=None if state is None else state[0],
        conv0=None if state is None else state[1],
        chunk=chunk)
    return x + y, new_state


def _select_attn(params, g_idx, n_shared):
    return jax.tree.map(lambda p: p[g_idx % n_shared], params["attn"])


def hybrid_forward_train(params, tokens, cfg: ArchConfig,
                         mesh: Optional[Mesh] = None) -> jax.Array:
    b, s = tokens.shape
    x = embed_tokens(params, tokens, cfg, mesh)
    positions = build_positions(cfg, b, s)
    groups, per, tail = _hybrid_layout(cfg)
    nsh = cfg.hybrid_shared_attn_blocks

    def group_body(xc, inp):
        g_idx, g_params = inp
        ap = _select_attn(params, g_idx, nsh)
        xc, _ = attn_apply(ap, xc, cfg=cfg, mesh=mesh,
                           positions=positions, mode="train")
        from repro.models.transformer import ffn_apply
        xc = ffn_apply(ap, xc, cfg, mesh)

        def mamba_body(xi, lp):
            xi, _ = _mamba_block(lp, xi, cfg, mesh)
            return xi, None

        body = jax.checkpoint(mamba_body) if cfg.remat else mamba_body
        xc, _ = _loop(cfg, body, xc, g_params, per)
        return xc, None

    gb = jax.checkpoint(group_body) if cfg.remat else group_body
    if groups:
        x, _ = _loop(cfg, gb, x, (jnp.arange(groups), params["mamba"]),
                     groups)
    if tail:
        def mb(xi, lp):
            xi, _ = _mamba_block(lp, xi, cfg, mesh)
            return xi, None
        body = jax.checkpoint(mb) if cfg.remat else mb
        x, _ = _loop(cfg, body, x, params["mamba_tail"], tail)
    return lm_head(params, x, cfg, mesh)


def init_hybrid_cache(cfg: ArchConfig, batch: int, max_len: int):
    dtype = _dtype(cfg)
    groups, per, tail = _hybrid_layout(cfg)
    d_in, nh, n = ssm_lib.ssm_dims(cfg)
    cw = cfg.ssm_conv_width
    mk_ssm = lambda *lead: (
        jnp.zeros(lead + (batch, nh, n, cfg.ssm_head_dim), jnp.float32),
        jnp.zeros(lead + (batch, cw - 1, d_in + 2 * n), dtype))
    cache = {
        "mamba": mk_ssm(groups, per),
        "attn": {
            "k": jnp.zeros((groups, batch, max_len, cfg.n_kv_heads,
                            cfg.head_dim), dtype),
            "v": jnp.zeros((groups, batch, max_len, cfg.n_kv_heads,
                            cfg.head_dim), dtype),
        },
    }
    if tail:
        cache["mamba_tail"] = mk_ssm(tail)
    return cache


def hybrid_decode_step(params, token, cache, cache_len, cfg: ArchConfig,
                       mesh: Optional[Mesh] = None):
    b = token.shape[0]
    x = embed_tokens(params, token, cfg, mesh)
    positions = build_positions(cfg, b, 1, offset=cache_len)
    groups, per, tail = _hybrid_layout(cfg)
    nsh = cfg.hybrid_shared_attn_blocks

    def group_body(xc, inp):
        g_idx, g_params, g_state, g_kv = inp
        ap = _select_attn(params, g_idx, nsh)
        xc, new_kv = attn_apply(ap, xc, cfg=cfg, mesh=mesh,
                                positions=positions, mode="decode",
                                cache=g_kv, cache_len=cache_len)
        from repro.models.transformer import ffn_apply
        xc = ffn_apply(ap, xc, cfg, mesh)

        def mamba_body(xi, inp2):
            lp, st = inp2
            xi, new_st = _mamba_block(lp, xi, cfg, mesh, state=st, chunk=1)
            return xi, new_st

        xc, new_states = _loop(cfg, mamba_body, xc, (g_params, g_state),
                               per)
        return xc, (new_states, new_kv)

    if groups:
        x, (new_mamba, new_kv) = _loop(
            cfg, group_body, x,
            (jnp.arange(groups), params["mamba"], cache["mamba"],
             cache["attn"]), groups)
        new_cache = {"mamba": new_mamba, "attn": new_kv}
    else:
        new_cache = {"mamba": cache["mamba"], "attn": cache["attn"]}
    if tail:
        def mb(xi, inp2):
            lp, st = inp2
            xi, new_st = _mamba_block(lp, xi, cfg, mesh, state=st, chunk=1)
            return xi, new_st
        x, new_tail = _loop(cfg, mb, x,
                            (params["mamba_tail"], cache["mamba_tail"]),
                            tail)
        new_cache["mamba_tail"] = new_tail
    logits = lm_head(params, x, cfg, mesh)[:, 0]
    return logits, new_cache


def hybrid_prefill(params, tokens, cfg: ArchConfig,
                   mesh: Optional[Mesh] = None):
    """Returns (last logits (B,V), cache at len = tokens.shape[1])."""
    b, s = tokens.shape
    x = embed_tokens(params, tokens, cfg, mesh)
    positions = build_positions(cfg, b, s)
    groups, per, tail = _hybrid_layout(cfg)
    nsh = cfg.hybrid_shared_attn_blocks

    def group_body(xc, inp):
        g_idx, g_params = inp
        ap = _select_attn(params, g_idx, nsh)
        xc, kv = attn_apply(ap, xc, cfg=cfg, mesh=mesh,
                            positions=positions, mode="prefill")
        from repro.models.transformer import ffn_apply
        xc = ffn_apply(ap, xc, cfg, mesh)

        def mamba_body(xi, lp):
            xi, st = _mamba_block(lp, xi, cfg, mesh)
            return xi, st

        body = jax.checkpoint(mamba_body) if cfg.remat else mamba_body
        xc, states = _loop(cfg, body, xc, g_params, per)
        return xc, (states, kv)

    gb = jax.checkpoint(group_body) if cfg.remat else group_body
    if groups:
        x, (mamba_states, kvs) = _loop(
            cfg, gb, x, (jnp.arange(groups), params["mamba"]), groups)
        cache = {"mamba": mamba_states, "attn": kvs}
    else:  # tail-only stacks (roofline probes)
        empty = init_hybrid_cache(cfg, b, s)
        cache = {"mamba": empty["mamba"], "attn": empty["attn"]}
    if tail:
        def mb(xi, lp):
            xi, st = _mamba_block(lp, xi, cfg, mesh)
            return xi, st
        body = jax.checkpoint(mb) if cfg.remat else mb
        x, tail_states = _loop(cfg, body, x, params["mamba_tail"], tail)
        cache["mamba_tail"] = tail_states
    logits = lm_head(params, x[:, -1:], cfg, mesh)[:, 0]
    return logits, cache


def hybrid_param_pspecs(cfg: ArchConfig, mesh: Mesh) -> dict:
    dp = dp_axes_of(mesh) or None
    mamba_spec = {
        "ln": P(None, None, None),
        "in_proj": P(None, None, dp, "model"),
        "conv_w": P(None, None, None, "model"),
        "conv_b": P(None, None, "model"),
        "a_log": P(None, None, None),
        "dt_bias": P(None, None, None),
        "d_skip": P(None, None, None),
        "norm_scale": P(None, None, "model"),
        "out_proj": P(None, None, "model", dp),
    }
    out = {
        "embed": ({"hash_tables": P(None, None, "model")}
                  if cfg.embedding == "bbit_hash"
                  else {"table": P(None, "model")}),
        "final_norm": P(None),
        "lm_head": P(dp, "model"),
        "mamba": mamba_spec,
        "attn": attn_pspecs(cfg, dp, stacked=True),
    }
    groups, per, tail = _hybrid_layout(cfg)
    if tail:
        out["mamba_tail"] = jax.tree.map(
            lambda s: P(*s[1:]), mamba_spec,
            is_leaf=lambda s: isinstance(s, P))
    return out


# ---------------------------------------------------------------------------
# xLSTM stack
# ---------------------------------------------------------------------------
def _xlstm_layout(cfg: ArchConfig) -> Tuple[int, int]:
    per = cfg.slstm_every - 1        # mLSTM blocks per group
    groups = cfg.n_layers // cfg.slstm_every
    return groups, per


def init_xlstm_stack_params(cfg: ArchConfig, key) -> dict:
    dtype = _dtype(cfg)
    groups, per = _xlstm_layout(cfg)
    k_emb, k_m, k_s = jax.random.split(key, 3)

    def init_m(kk):
        p = xlstm_lib.init_mlstm_params(cfg, kk, dtype)
        p["ln"] = jnp.ones((cfg.d_model,), dtype)
        return p

    def init_s(kk):
        p = xlstm_lib.init_slstm_params(cfg, kk, dtype)
        p["ln"] = jnp.ones((cfg.d_model,), dtype)
        return p

    params = init_embed_params(cfg, k_emb, dtype)
    km = jax.random.split(k_m, groups * per).reshape(groups, per)
    params["mlstm"] = jax.vmap(jax.vmap(init_m))(km)
    params["slstm"] = jax.vmap(init_s)(jax.random.split(k_s, groups))
    return params


def _mlstm_block(lp, x, cfg, mesh, state=None, chunk=128):
    h = rmsnorm(x, lp["ln"], cfg.norm_eps)
    y, st = xlstm_lib.mlstm_forward(
        {k: v for k, v in lp.items() if k != "ln"}, h, cfg,
        state=state, chunk=chunk)
    return x + y, st


def _slstm_block(lp, x, cfg, mesh, state=None):
    h = rmsnorm(x, lp["ln"], cfg.norm_eps)
    y, st = xlstm_lib.slstm_forward(
        {k: v for k, v in lp.items() if k != "ln"}, h, cfg, state=state)
    return x + y, st


def xlstm_forward_train(params, tokens, cfg: ArchConfig,
                        mesh: Optional[Mesh] = None) -> jax.Array:
    x = embed_tokens(params, tokens, cfg, mesh)
    groups, per = _xlstm_layout(cfg)

    def group_body(xc, inp):
        g_m, g_s = inp

        def m_body(xi, lp):
            xi, _ = _mlstm_block(lp, xi, cfg, mesh)
            return xi, None

        body = jax.checkpoint(m_body) if cfg.remat else m_body
        xc, _ = _loop(cfg, body, xc, g_m, per)
        xc, _ = _slstm_block(g_s, xc, cfg, mesh)
        return xc, None

    gb = jax.checkpoint(group_body) if cfg.remat else group_body
    x, _ = _loop(cfg, gb, x, (params["mlstm"], params["slstm"]), groups)
    return lm_head(params, x, cfg, mesh)


def init_xlstm_cache(cfg: ArchConfig, batch: int, max_len: int):
    del max_len                      # recurrent: O(1) state
    groups, per = _xlstm_layout(cfg)
    d_in, p = xlstm_lib.xlstm_dims(cfg)
    h = cfg.n_heads
    ps = cfg.d_model // h
    zeros = lambda *s: jnp.zeros(s, jnp.float32)
    return {
        "mlstm": (zeros(groups, per, batch, h, p, p),
                  zeros(groups, per, batch, h, p),
                  jnp.full((groups, per, batch, h), -1e30, jnp.float32)),
        "slstm": (zeros(groups, batch, h, ps),
                  zeros(groups, batch, h, ps) + 1.0,
                  zeros(groups, batch, h, ps),
                  zeros(groups, batch, h, ps) - 1e30),
    }


def xlstm_apply_with_state(params, tokens, cache, cfg: ArchConfig,
                           mesh: Optional[Mesh] = None, chunk=128):
    """Shared prefill/decode: runs tokens through, carrying states."""
    x = embed_tokens(params, tokens, cfg, mesh)
    groups, per = _xlstm_layout(cfg)

    def group_body(xc, inp):
        g_m, g_s, st_m, st_s = inp

        def m_body(xi, inp2):
            lp, st = inp2
            xi, new = _mlstm_block(lp, xi, cfg, mesh, state=st,
                                   chunk=chunk)
            return xi, new

        xc, new_m = _loop(cfg, m_body, xc, (g_m, st_m), per)
        xc, new_s = _slstm_block(g_s, xc, cfg, mesh, state=st_s)
        return xc, (new_m, new_s)

    x, (new_m, new_s) = _loop(
        cfg, group_body, x,
        (params["mlstm"], params["slstm"], cache["mlstm"],
         cache["slstm"]), groups)
    return x, {"mlstm": new_m, "slstm": new_s}


def xlstm_prefill(params, tokens, cfg: ArchConfig,
                  mesh: Optional[Mesh] = None):
    cache = init_xlstm_cache(cfg, tokens.shape[0], 0)
    x, new_cache = xlstm_apply_with_state(params, tokens, cache, cfg, mesh)
    return lm_head(params, x[:, -1:], cfg, mesh)[:, 0], new_cache


def xlstm_decode_step(params, token, cache, cache_len, cfg: ArchConfig,
                      mesh: Optional[Mesh] = None):
    del cache_len                    # recurrent state carries position
    x, new_cache = xlstm_apply_with_state(params, token, cache, cfg,
                                          mesh, chunk=1)
    return lm_head(params, x, cfg, mesh)[:, 0], new_cache


def xlstm_param_pspecs(cfg: ArchConfig, mesh: Mesh) -> dict:
    dp = dp_axes_of(mesh) or None
    lead2 = (None, None)
    m_spec = {
        "ln": P(*lead2, None),
        "up_proj": P(*lead2, dp, "model"),
        "wq": P(*lead2, None, None, None),
        "wk": P(*lead2, None, None, None),
        "wv": P(*lead2, None, None, None),
        "w_gates": P(*lead2, "model", None),
        "gate_bias": P(*lead2, None),
        "out_norm": P(*lead2, "model"),
        "down_proj": P(*lead2, "model", dp),
    }
    s_spec = {
        "ln": P(None, None),
        "w_in": P(None, dp, "model"),
        "r": P(None, None, None, None),
        "bias": P(None, "model"),
        "out_norm": P(None, None),
        "out_proj": P(None, dp, "model"),
    }
    return {
        "embed": ({"hash_tables": P(None, None, "model")}
                  if cfg.embedding == "bbit_hash"
                  else {"table": P(None, "model")}),
        "final_norm": P(None),
        "lm_head": P(dp, "model"),
        "mlstm": m_spec,
        "slstm": s_spec,
    }
