"""Mesh / sharding helpers shared by the launchers and trainers.

Axis convention (the assignment's production mesh):
  single-pod:  (data=16, model=16)            — 256 chips
  multi-pod:   (pod=2, data=16, model=16)     — 512 chips

"Batch-like" tensors shard over ``(pod, data)``; "model-like" dims over
``model``.  FSDP-style parameter sharding additionally splits the
largest parameter dim over the data axes (required for ≥67B configs).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """All batch-parallel axes present in the mesh ('pod' first)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(mesh: Mesh, extra_dims: int = 1) -> P:
    """P((pod, data), None, ...) for a batch-leading tensor."""
    return P(data_axes(mesh), *([None] * extra_dims))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def shard(mesh: Mesh, *axes) -> NamedSharding:
    return NamedSharding(mesh, P(*axes))


def dp_size(mesh: Mesh) -> int:
    out = 1
    for a in data_axes(mesh):
        out *= mesh.shape[a]
    return out


def mp_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def constrain(x, mesh: Mesh, *axes):
    """with_sharding_constraint shorthand used inside model code."""
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*axes)))
