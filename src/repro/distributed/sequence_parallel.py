"""Sequence parallelism: long-context sharding over the data axis.

Two primitives (used by the long_500k cells, where global batch = 1 and
the data axis would otherwise idle — see EXPERIMENTS.md §Perf):

  * ``merge_partial_attention`` — distributed online-softmax: each shard
    attends over its local KV slice; partial (max, denom, numerator)
    stats merge with two psums.  Exact, not approximate.
  * ``seq_parallel_ssm_scan``   — inter-chunk SSM recurrence composed
    across shards.  The SSD recurrence  h' = A·h + B  is associative, so
    per-shard cumulative (A, B) operators are all-gathered (they are
    tiny: batch × heads × state) and each shard applies its exclusive
    prefix locally — one small collective instead of a serial chain.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def merge_partial_attention(
    local_max: jax.Array,     # (..., q) per-shard running max of scores
    local_denom: jax.Array,   # (..., q) Σ exp(score - local_max)
    local_num: jax.Array,     # (..., q, d) Σ exp(score - local_max)·V
    axis_name: str,
) -> jax.Array:
    """Exact softmax-attention output from per-shard partial stats."""
    g_max = jax.lax.pmax(local_max, axis_name)
    corr = jnp.exp(local_max - g_max)
    denom = jax.lax.psum(local_denom * corr, axis_name)
    num = jax.lax.psum(local_num * corr[..., None], axis_name)
    return num / denom[..., None]


def seq_parallel_ssm_scan(
    a_cum: jax.Array,   # (..., state) product of decay over local chunk
    b_cum: jax.Array,   # (..., state) local chunk's accumulated input
    h0: jax.Array,      # (..., state) global initial state
    axis_name: str,
    axis_index: jax.Array,
) -> jax.Array:
    """Returns each shard's *incoming* state h_in.

    Local chunk maps h_in → a_cum·h_in + b_cum.  Gathers the (a, b)
    operators from all shards and composes the exclusive prefix locally.
    """
    a_all = jax.lax.all_gather(a_cum, axis_name)   # (S, ..., state)
    b_all = jax.lax.all_gather(b_cum, axis_name)
    # h0 is replicated; make it device-varying so the scan carry type
    # matches the varying (a, b) operands under shard_map.
    h0 = h0 + jnp.zeros_like(h0) * jax.lax.axis_index(axis_name).astype(
        h0.dtype)

    def body(carry, ab):
        a, b = ab
        return a * carry + b, carry  # emit the state *before* this shard

    _, h_before = jax.lax.scan(body, h0, (a_all, b_all))
    # h_before[i] is the incoming state of shard i
    return jnp.take(h_before, axis_index, axis=0)
