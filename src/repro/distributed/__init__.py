"""Distributed runtime: shardings, compression, SP, PP, collectives."""
from repro.distributed.shardings import (
    data_axes, batch_spec, replicated, shard, dp_size, mp_size, constrain,
)
from repro.distributed.grad_compression import (
    compressed_allreduce_mean, tree_compressed_allreduce_mean,
    init_error_state,
)
from repro.distributed.sequence_parallel import (
    merge_partial_attention, seq_parallel_ssm_scan,
)
from repro.distributed.pipeline import pipelined_apply
from repro.distributed.collectives import (
    collective_stats_from_hlo,
    collective_bytes_from_hlo, psum_mean, COLLECTIVE_OPS,
)

__all__ = [
    "data_axes", "batch_spec", "replicated", "shard", "dp_size", "mp_size",
    "constrain",
    "compressed_allreduce_mean", "tree_compressed_allreduce_mean",
    "init_error_state",
    "merge_partial_attention", "seq_parallel_ssm_scan",
    "pipelined_apply",
    "collective_bytes_from_hlo", "collective_stats_from_hlo", "psum_mean", "COLLECTIVE_OPS",
]
