"""Distributed runtime: processes, shardings, compression, SP, PP,
collectives."""
from repro.distributed.runtime import (
    ProcessRuntime, current_rank, current_runtime, heartbeat,
    init_runtime, mesh_over_processes, process_slot_range,
    read_heartbeats, replicate_across_processes,
)
from repro.distributed.shardings import (
    data_axes, batch_spec, replicated, shard, dp_size, mp_size, constrain,
)
from repro.distributed.grad_compression import (
    compressed_allreduce_mean, tree_compressed_allreduce_mean,
    init_error_state,
)
from repro.distributed.sequence_parallel import (
    merge_partial_attention, seq_parallel_ssm_scan,
)
from repro.distributed.pipeline import pipelined_apply
from repro.distributed.collectives import (
    collective_stats_from_hlo,
    collective_bytes_from_hlo, psum_mean, COLLECTIVE_OPS,
)

__all__ = [
    "ProcessRuntime", "init_runtime", "current_runtime", "current_rank",
    "mesh_over_processes", "process_slot_range",
    "replicate_across_processes", "heartbeat", "read_heartbeats",
    "data_axes", "batch_spec", "replicated", "shard", "dp_size", "mp_size",
    "constrain",
    "compressed_allreduce_mean", "tree_compressed_allreduce_mean",
    "init_error_state",
    "merge_partial_attention", "seq_parallel_ssm_scan",
    "pipelined_apply",
    "collective_bytes_from_hlo", "collective_stats_from_hlo", "psum_mean", "COLLECTIVE_OPS",
]
