"""GPipe-style pipeline parallelism over the ``pod`` axis (optional).

2-stage microbatch pipelining inside ``shard_map``: layer stacks are
split into S contiguous stages (one per pod); microbatches stream
through with ``ppermute`` boundary transfers.  With M microbatches the
bubble fraction is (S-1)/(M+S-1) — at S=2, M=8 that is 1/9.

The forward is written with ``lax.fori_loop`` over M+S-1 ticks; JAX
autodiff through the loop gives the backward schedule (activations
rematerialized per-stage via ``jax.checkpoint`` on the stage fn).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def pipelined_apply(
    stage_fn: Callable,     # (stage_params, x) -> y, same shape
    stage_params,           # pytree whose leaves lead with [n_stages_local=1]
    x_micro: jax.Array,     # (M, micro_batch, ...) this pod's input copy
    axis_name: str = "pod",
) -> jax.Array:
    """Runs the local stage over M microbatches with ppermute handoffs.

    Every pod holds the SAME x_micro (inputs replicated over the pipe
    axis); stage 0 consumes microbatch m at tick m, the last stage's
    outputs are collected and broadcast back.  Returns (M, micro, ...).
    """
    try:
        s = jax.lax.axis_size(axis_name)
    except AttributeError:  # jax<0.5: psum of a python scalar is static
        s = jax.lax.psum(1, axis_name)
    sid = jax.lax.axis_index(axis_name)
    m = x_micro.shape[0]
    ticks = m + s - 1
    fn = jax.checkpoint(stage_fn)

    perm_fwd = [(i, i + 1) for i in range(s - 1)]

    def tick(t, carry):
        inflight, outputs = carry
        # stage input: stage 0 picks microbatch t (clamped), others take
        # the handoff from the previous stage.
        mb = jnp.clip(t, 0, m - 1)
        x_in = jnp.where(sid == 0, x_micro[mb], inflight)
        y = fn(jax.tree.map(lambda p: p[0], stage_params), x_in)
        # last stage writes its result for microbatch t-(s-1)
        out_idx = jnp.clip(t - (s - 1), 0, m - 1)
        is_valid = jnp.logical_and(sid == s - 1, t >= s - 1)
        outputs = jnp.where(
            is_valid,
            outputs.at[out_idx].set(y),
            outputs)
        # handoff to next stage
        inflight = jax.lax.ppermute(y, axis_name, perm_fwd)
        return inflight, outputs

    # Initial carries must be device-varying to match the loop body's
    # output types under shard_map (ppermute/psum results vary).
    vary = jnp.zeros((), x_micro.dtype) * sid.astype(x_micro.dtype)
    inflight0 = jnp.zeros_like(x_micro[0]) + vary
    outputs0 = jnp.zeros_like(x_micro) + vary
    _, outputs = jax.lax.fori_loop(0, ticks, tick, (inflight0, outputs0))
    # broadcast final-stage outputs to every pod (they all need the loss)
    outputs = jax.lax.psum(
        jnp.where(sid == s - 1, outputs, jnp.zeros_like(outputs)),
        axis_name)
    return outputs
