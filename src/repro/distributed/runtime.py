"""Multi-process (multi-host) runtime over ``jax.distributed``.

PR 7 made the streaming trainer survive anything that can happen to a
single process; this layer makes the PROCESS itself a replaceable part.
A training gang is ``procs`` cooperating processes, each owning a
contiguous block of the logical shard slots (``process_slot_range``)
and a fixed block of the global device mesh
(``mesh_over_processes``).  Everything topology-shaped that the rest
of the repo needs lives here:

  * ``init_runtime`` — the coordinator bootstrap.  For ``procs > 1``
    it selects the gloo CPU collectives backend and calls
    ``jax.distributed.initialize``; for ``procs == 1`` it touches
    nothing (single-process runs must not pay a distributed-runtime
    tax, and configuring gloo without a coordinator breaks CPU backend
    init).  It also tells ``repro.ft.faults`` this process's rank, so
    rank-targeted fault events (``rank=k``) fire on the right process;
  * ``ProcessRuntime`` — the passive record the trainer threads
    through: gang size, rank, per-process device count, and the run
    directory used for heartbeat files;
  * ``mesh_over_processes`` — the global (data, model) mesh with
    devices sorted by ``(process_index, id)`` and exactly ``d_local``
    devices per process, so process p's devices occupy mesh rows
    ``[p·d_local, (p+1)·d_local)`` — which is what makes a process's
    contiguous slot block line up with a contiguous run of mesh rows
    and lets ``jax.make_array_from_process_local_data`` assemble the
    stacked batch from purely local reads;
  * ``replicate_across_processes`` — host pytree → fully-replicated
    global arrays via ``jax.make_array_from_callback`` (a plain
    ``device_put`` cannot build arrays spanning non-addressable
    devices);
  * **heartbeats** — each rank writes an atomic
    ``<run_dir>/hb/rank_<r>.json`` at every shard boundary with its
    rank, global step and wall-clock, giving the supervisor (and a
    human with ``cat``) a liveness/progress view that does not depend
    on the collectives being healthy.

The process topology is deliberately NOT part of the run fingerprint:
the shard-ownership policy (``"contiguous_slots"``) is, so resume
refuses a run whose slot→process mapping rule changed, while the gang
SIZE rides the sanctioned topology-lineage record exactly like the
physical device count — a checkpoint written by N processes resumes on
M ≠ N under ``elastic=True`` (see ``train.streaming``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Optional, Tuple

import numpy as np

from repro.ft import faults

__all__ = [
    "ProcessRuntime", "init_runtime", "current_runtime", "current_rank",
    "mesh_over_processes", "replicate_across_processes",
    "process_slot_range", "heartbeat", "read_heartbeats",
]

SHARD_OWNERSHIP = "contiguous_slots"

_CURRENT: Optional["ProcessRuntime"] = None


def current_runtime() -> Optional["ProcessRuntime"]:
    """The runtime ``init_runtime`` registered (None before init —
    i.e. in every classic single-process run)."""
    return _CURRENT


def current_rank() -> int:
    """This process's gang rank (0 when no runtime was initialized)."""
    return _CURRENT.rank if _CURRENT is not None else 0


@dataclasses.dataclass(frozen=True)
class ProcessRuntime:
    """One process's view of the training gang."""
    procs: int = 1                 # gang size (1 = classic single-process)
    rank: int = 0                  # this process's id in [0, procs)
    coordinator: str = ""          # "host:port" ("" when single-process)
    local_devices: int = 1         # devices this process contributes
    run_dir: Optional[str] = None  # heartbeat / gang bookkeeping root

    @property
    def is_multiprocess(self) -> bool:
        return self.procs > 1

    @property
    def is_leader(self) -> bool:
        return self.rank == 0


def init_runtime(
    procs: int = 1,
    rank: int = 0,
    coordinator: Optional[str] = None,
    run_dir: Optional[str] = None,
) -> ProcessRuntime:
    """Bootstraps this process into a ``procs``-wide gang.

    Must run before the first jax computation (``jax.distributed
    .initialize`` cannot attach to an already-initialized backend).
    Single-process (``procs == 1``) is a no-op beyond building the
    record — in particular the gloo collectives config is NOT touched:
    selecting gloo without a coordinator leaves the CPU client half
    built and every later backend call fails.
    """
    if procs < 1:
        raise ValueError(f"procs must be >= 1, got {procs}")
    if not 0 <= rank < procs:
        raise ValueError(f"rank {rank} outside [0, {procs})")
    if procs > 1:
        if not coordinator:
            raise ValueError(
                "multi-process init needs a coordinator address "
                "(host:port) shared by every rank")
        import jax
        try:
            # CPU cross-process collectives ship via gloo; the config
            # knob must be set BEFORE distributed.initialize builds the
            # backend.  Non-CPU builds may not expose it — harmless,
            # their collectives don't route through it.
            jax.config.update("jax_cpu_collectives_implementation",
                              "gloo")
        except Exception:  # noqa: BLE001 — knob absent on this build
            pass
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=procs, process_id=rank)
    faults.set_rank(rank)
    rt = ProcessRuntime(procs=procs, rank=rank,
                        coordinator=coordinator or "",
                        local_devices=_local_device_count(),
                        run_dir=run_dir)
    global _CURRENT
    _CURRENT = rt
    if run_dir:
        heartbeat(rt, phase="init")
    return rt


def _local_device_count() -> int:
    import jax
    return jax.local_device_count()


def mesh_over_processes(d_local: int, *, model_parallel: int = 1):
    """The gang's global (data, model) mesh: ``d_local`` devices per
    process, ordered by ``(process_index, id)``.

    Process p's devices land at data rows ``[p·d_local, (p+1)·d_local)``
    — the invariant ``process_slot_range`` and the local-batch assembly
    in ``train.data_parallel.device_put_process_local`` rely on.  Every
    process must contribute at least ``d_local`` devices.
    """
    import jax
    from jax.sharding import Mesh

    by_proc: dict = {}
    for d in jax.devices():
        by_proc.setdefault(d.process_index, []).append(d)
    chosen = []
    for p in sorted(by_proc):
        devs = sorted(by_proc[p], key=lambda d: d.id)
        if len(devs) < d_local:
            raise ValueError(
                f"process {p} has {len(devs)} devices but the mesh "
                f"needs {d_local} per process")
        chosen.extend(devs[:d_local])
    n = len(chosen)
    if n % model_parallel:
        raise ValueError(
            f"{n} devices not divisible by model_parallel="
            f"{model_parallel}")
    arr = np.asarray(chosen).reshape(n // model_parallel, model_parallel)
    return Mesh(arr, ("data", "model"))


def replicate_across_processes(tree: Any, mesh) -> Any:
    """Host pytree → fully-replicated global arrays on ``mesh``.

    ``jax.device_put`` can only target addressable devices; a
    replicated array on a multi-process mesh spans devices this
    process cannot address, so each leaf is assembled with
    ``make_array_from_callback`` (every process feeds its local shards
    from its own identical host copy — the standard same-value-on-
    every-process contract).
    """
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    rep = NamedSharding(mesh, P())

    def _leaf(x):
        host = np.asarray(x)
        return jax.make_array_from_callback(
            host.shape, rep, lambda idx: host[idx])

    return jax.tree.map(_leaf, tree)


def process_slot_range(logical: int, procs: int,
                       rank: int) -> Tuple[int, int]:
    """The contiguous block of logical shard slots rank ``rank`` owns.

    ``logical`` must divide evenly over the gang — uneven ownership
    would give processes different step counts within a group and
    deadlock the collectives.
    """
    if logical % procs:
        raise ValueError(
            f"data_parallel={logical} logical shard slots cannot split "
            f"evenly over {procs} processes — choose procs dividing "
            "the logical world")
    per = logical // procs
    return rank * per, (rank + 1) * per


# ------------------------------------------------------- heartbeats ----

def _hb_dir(run_dir: str) -> str:
    return os.path.join(run_dir, "hb")


def heartbeat(rt: ProcessRuntime, *, step: int = 0,
              shards_done: int = 0, phase: str = "train") -> None:
    """Atomically publishes this rank's liveness/progress record."""
    if not rt.run_dir:
        return
    d = _hb_dir(rt.run_dir)
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"rank_{rt.rank:05d}.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"rank": rt.rank, "procs": rt.procs, "phase": phase,
                   "step": int(step), "shards_done": int(shards_done),
                   "time": time.time(), "pid": os.getpid()}, f)
    os.replace(tmp, path)


def read_heartbeats(run_dir: str) -> dict:
    """All ranks' latest heartbeat records, keyed by rank."""
    out: dict = {}
    d = _hb_dir(run_dir)
    try:
        names = os.listdir(d)
    except FileNotFoundError:
        return out
    for name in names:
        if not (name.startswith("rank_") and name.endswith(".json")):
            continue
        try:
            with open(os.path.join(d, name)) as f:
                rec = json.load(f)
            out[int(rec["rank"])] = rec
        except (OSError, ValueError, KeyError):
            continue
    return out
