"""b-bit gradient compression with error feedback (beyond-paper feature).

The paper compresses *features* to b bits; the same storage argument
applies to the data-parallel gradient exchange, which dominates the
collective term for the linear model at scale.  We implement
EF-compressed all-reduce (QSGD/EF-SGD family):

    q_t   = Q_b(g_t + e_t)            blockwise absmax int8 (or sign+scale)
    e_t+1 = (g_t + e_t) - deQ(q_t)    local error memory
    ĝ_t   = (1/S) Σ_shards deQ(q_t)   via int8 all_gather + local sum

Wire bytes per step drop 4× (int8) or ~32× (sign1) vs fp32 ring
all-reduce — visible in the compiled HLO as int8 all-gathers, which is
exactly how the §Perf collective-term iteration measures it.

Everything here runs inside ``shard_map`` with a named data axis.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def _blockwise_quantize(g: jax.Array, block: int) -> Tuple[jax.Array, jax.Array]:
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def _blockwise_dequantize(q: jax.Array, scale: jax.Array, shape,
                          block: int) -> jax.Array:
    flat = (q.astype(jnp.float32).reshape(-1, block)
            * scale[:, None]).reshape(-1)
    size = 1
    for s in shape:
        size *= s
    return flat[:size].reshape(shape)


def compressed_allreduce_mean(
    g: jax.Array,
    err: jax.Array,
    axis_name: str,
    *,
    block: int = 256,
    bits: int = 8,
) -> Tuple[jax.Array, jax.Array]:
    """EF int8 (bits=8) or sign (bits=1) all-reduce-mean of one tensor.

    Must be called inside shard_map with ``axis_name`` bound.
    Returns (mean gradient f32, new error memory).
    """
    corrected = g.astype(jnp.float32) + err
    if bits == 8:
        q, scale = _blockwise_quantize(corrected, block)
        local_deq = _blockwise_dequantize(q, scale, g.shape, block)
        # int8 payload + tiny f32 scale vector on the wire
        q_all = jax.lax.all_gather(q, axis_name)          # (S, nb, block) i8
        s_all = jax.lax.all_gather(scale, axis_name)      # (S, nb) f32
        summed = jnp.einsum(
            "snb,sn->nb", q_all.astype(jnp.float32), s_all)
        mean = (summed.reshape(-1)[: corrected.size].reshape(g.shape)
                / jax.lax.psum(1, axis_name))
    elif bits == 1:
        scale = jnp.mean(jnp.abs(corrected))
        q = jnp.sign(corrected).astype(jnp.int8)
        local_deq = q.astype(jnp.float32) * scale
        q_all = jax.lax.all_gather(q, axis_name)
        s_all = jax.lax.all_gather(scale, axis_name)
        mean = jnp.einsum("s...,s->...", q_all.astype(jnp.float32), s_all
                          ) / jax.lax.psum(1, axis_name)
    else:
        raise ValueError("bits must be 1 or 8")
    new_err = corrected - local_deq
    return mean, new_err


def tree_compressed_allreduce_mean(grads, errs, axis_name: str,
                                   *, block: int = 256, bits: int = 8):
    """Pytree version; errs has the same structure as grads."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errs)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        mg, ne = compressed_allreduce_mean(g, e, axis_name,
                                           block=block, bits=bits)
        out_g.append(mg)
        out_e.append(ne)
    return treedef.unflatten(out_g), treedef.unflatten(out_e)


def init_error_state(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
