"""Thin collective wrappers + HLO collective-bytes accounting helpers."""
from __future__ import annotations

import re
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'f32[128,256]' → byte count (0 for unparsable/token types)."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dtype, dims = m.groups()
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_OP_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")

_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(rhs: str) -> int:
    """Participant count per replica group (0 if unannotated)."""
    m = _GROUPS_EXPLICIT_RE.search(rhs)
    if m:
        return m.group(1).count(",") + 1
    m = _GROUPS_IOTA_RE.search(rhs)
    if m:
        return int(m.group(2))  # iota [num_groups, group_size]
    return 0


def collective_stats_from_hlo(hlo_text: str):
    """Per-instruction collective stats: [{op, bytes, group_size}].

    ``bytes`` is the RESULT shape size landing on each participant;
    the roofline applies op-specific ring multipliers using group_size.
    """
    stats = []
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith(("//", "#")) or " = " not in s:
            continue
        _, rhs = s.split(" = ", 1)
        opm = _OP_RE.search(rhs)
        if not opm:
            continue
        op = opm.group(1)
        result_part = rhs[: opm.start()]
        nbytes = sum(_shape_bytes(f"{d}[{dims}]") for d, dims
                     in _SHAPE_RE.findall(result_part))
        stats.append({"op": op, "bytes": nbytes,
                      "group_size": _group_size(rhs)})
    return stats


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sums result-shape bytes of every collective op in an HLO dump.

    Handles layouts (``f32[8,16]{1,0}``), tuple results, and async
    ``-start``/``-done`` pairs (counts the start, skips the done).  The
    accounted size is the RESULT shape — the bytes that land on each
    participant, the quantity the roofline's collective term needs.
    """
    out = {k: 0 for k in COLLECTIVE_OPS}
    out["count"] = 0
    for st in collective_stats_from_hlo(hlo_text):
        out[st["op"]] += st["bytes"]
        out["count"] += 1
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    return out


def psum_mean(x, axis_name: str):
    """Cross-device mean over ``axis_name`` — works on pytrees, so a
    whole gradient tree all-reduces in one call.

    Two properties the data-parallel streaming step relies on:

      * dtype preservation: the participant count is cast to each
        leaf's dtype BEFORE the divide — ``psum(x) / psum(1)`` would
        promote via weak int typing (bf16 grads silently widen to
        f32);
      * one collective per dtype, not per leaf: same-dtype leaves are
        raveled and concatenated into a single fused all-reduce.
        Collective setup cost is per-op (measured ~1.3 ms/all-reduce
        on a fake-device CPU mesh, where it dominates a small model's
        step), and XLA does not reliably combine small all-reduces on
        every backend.
    """
    leaves, treedef = jax.tree.flatten(x)
    if not leaves:
        return x
    n = jax.lax.psum(1, axis_name)   # static: folded at trace time
    groups: dict = {}
    for i, v in enumerate(leaves):
        groups.setdefault(jnp.asarray(v).dtype, []).append(i)
    out = [None] * len(leaves)
    for dt, idxs in groups.items():
        count = jnp.asarray(n, dt)
        if len(idxs) == 1:
            i = idxs[0]
            out[i] = jax.lax.psum(leaves[i], axis_name) / count
            continue
        flat = jnp.concatenate([jnp.ravel(leaves[i]) for i in idxs])
        summed = jax.lax.psum(flat, axis_name) / count
        off = 0
        for i in idxs:
            size = int(np.prod(jnp.shape(leaves[i]), dtype=np.int64))
            out[i] = summed[off: off + size].reshape(
                jnp.shape(leaves[i]))
            off += size
    return jax.tree.unflatten(treedef, out)


def replica_groups_size(axis_name: str) -> jax.Array:
    return jax.lax.psum(1, axis_name)
