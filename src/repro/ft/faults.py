"""Deterministic, seeded fault injection for the streaming trainer.

The crash-equivalence tests (tests/test_fault_tolerance.py) and the
supervised-restart benchmark need *reproducible* production failures:
a process crash at train step N, a transient ``IOError`` on a shard
read, a torn (partially-persisted) checkpoint write, an injected slow
step for the straggler watchdog.  This module is the single switch for
all of them:

  * a ``FaultPlan`` is an ordered list of ``FaultEvent``s, each naming
    a hook site and a trigger (step / shard / checkpoint step) plus how
    many times it fires (``times=None`` = persistent — the model for a
    genuinely corrupt disk block, as opposed to a transient hiccup);
  * ``arm(plan)`` installs the plan process-wide; hook points in
    ``train.streaming.fit_streaming``, ``data.hashed_dataset
    .load_packed_shard`` and ``ckpt.checkpoint.save`` consult it.
    Every call site guards on the module global first::

        if faults._ACTIVE is not None:
            faults.on_train_step(step)

    so the unarmed cost is one global load + identity check — zero
    overhead on the hot path when no plan is armed (the default);
  * firing counts live ON the plan (``FaultEvent.fired``), so one plan
    armed across a supervised restart loop injects its crash exactly
    ``times`` times and then lets the retries succeed — which is what
    makes the crash-equivalence property testable in-process.

Injected failures are ordinary exceptions: ``InjectedCrash`` (a
``RuntimeError`` — the supervisor treats it like any worker death) and
a plain ``IOError`` for shard reads (so the reader's bounded
retry-with-backoff path handles it exactly like a real transient I/O
error).  The torn-checkpoint event is special: the hook *returns a
directive* and ``ckpt.checkpoint.save`` implements the tear itself
(write, truncate the payload, complete the rename + manifest update,
then crash) — simulating the real-world failure where the rename is
durable but the data pages never hit disk.

Cross-rank injection (the multi-process gang, ``distributed.runtime``):

  * every event carries an optional ``rank`` — it fires only in the
    process whose ``set_rank`` matches (``None`` = any rank), so one
    plan shipped to every worker kills exactly rank k;
  * ``"proc_kill"`` is a REAL death: ``SIGKILL`` to self at the named
    step — no Python cleanup, no exception, the exact way an OOM
    killer or `kill -9` takes a worker.  ``"manifest_write"`` kills
    rank 0 between writing the coordinated checkpoint's rank payloads
    and committing the step manifest (``ckpt.coordinated``) — the
    window that must leave the PREVIOUS checkpoint authoritative;
  * plans survive respawns: ``state_path`` persists each event's
    ``fired`` count (written before any kill/raise), so a ``times=1``
    kill does not re-fire after the supervisor restarts the gang —
    which is what makes the multi-process crash matrix terminate.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import signal
import time
from typing import List, Optional

__all__ = [
    "FaultEvent", "FaultPlan", "InjectedCrash", "arm", "arm_plan",
    "disarm", "active", "set_rank", "current_rank", "on_train_step",
    "on_shard_read", "on_ckpt_write", "on_manifest_write",
]


class InjectedCrash(RuntimeError):
    """A planned process-crash stand-in: raised out of a hook site and
    (in the supervised loop) handled exactly like a worker death."""


@dataclasses.dataclass
class FaultEvent:
    """One planned failure.

    ``site`` selects the hook:

      * ``"train_step"`` — raise ``InjectedCrash`` when the trainer's
        global step equals ``step``;
      * ``"slow_step"``  — sleep ``delay_s`` before that step runs (the
        straggler the ``StepWatchdog`` should flag);
      * ``"shard_read"`` — raise ``IOError`` from the packed-shard
        reader when it opens shard ``shard`` (``None`` = any shard);
      * ``"ckpt_write"`` — tear the checkpoint written at checkpoint
        step ``at_save`` (``None`` = the next save): the payload is
        truncated *after* the atomic rename completes, then
        ``InjectedCrash`` is raised;
      * ``"proc_kill"`` — ``SIGKILL`` to self before dispatching train
        step ``step``: a real `kill -9`, no cleanup, no exception;
      * ``"manifest_write"`` — kill the committing rank of a
        coordinated checkpoint at save step ``at_save`` (``None`` =
        the next save) AFTER every rank payload is durable but BEFORE
        the step manifest commits.

    ``rank`` scopes the event to one process of a multi-process gang
    (``None`` = any rank; single-process runs are rank 0).  ``times``
    bounds how often the event fires (``None`` = every match, the
    persistent-corruption model); ``fired`` counts firings.
    """
    site: str
    step: Optional[int] = None
    shard: Optional[int] = None
    at_save: Optional[int] = None
    rank: Optional[int] = None
    times: Optional[int] = 1
    delay_s: float = 0.0
    mode: str = "torn"
    fired: int = 0

    def _rank_matches(self) -> bool:
        return self.rank is None or self.rank == _RANK

    def _take(self) -> bool:
        if self.times is not None and self.fired >= self.times:
            return False
        self.fired += 1
        return True


@dataclasses.dataclass
class FaultPlan:
    """An ordered set of planned failures, armed process-wide via
    ``arm``/``arm_plan``.  The plan is stateful: each event remembers
    how often it fired, so the same plan object armed across a
    supervised restart sequence injects each failure exactly as
    scripted.

    ``state_path`` extends that statefulness across PROCESS deaths:
    firing counts are persisted there (atomically, BEFORE the failure
    is delivered) and re-loaded by ``load_state`` in the respawned
    worker — without it, a ``times=1`` process kill would re-fire on
    every restart and the gang could never finish.
    """
    events: List[FaultEvent]
    seed: int = 0
    state_path: Optional[str] = None

    def matching(self, site: str):
        return [e for e in self.events if e.site == site
                and e._rank_matches()]

    # ------------------------------ cross-process (de)serialization --
    def to_spec(self) -> dict:
        """JSON-safe description (fired counts excluded — those travel
        via ``state_path``), for shipping a plan to gang workers."""
        evs = []
        for e in self.events:
            d = dataclasses.asdict(e)
            d.pop("fired")
            evs.append(d)
        return {"events": evs, "seed": self.seed}

    @classmethod
    def from_spec(cls, spec: dict,
                  state_path: Optional[str] = None) -> "FaultPlan":
        plan = cls([FaultEvent(**ev) for ev in spec.get("events", [])],
                   seed=int(spec.get("seed", 0)),
                   state_path=state_path)
        plan.load_state()
        return plan

    # ------------------------------------- fired-count persistence ---
    def load_state(self) -> None:
        if not self.state_path:
            return
        try:
            with open(self.state_path) as f:
                fired = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError, OSError):
            return
        for i, ev in enumerate(self.events):
            ev.fired = int(fired.get(str(i), ev.fired))

    def persist_state(self) -> None:
        if not self.state_path:
            return
        tmp = self.state_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({str(i): ev.fired
                       for i, ev in enumerate(self.events)}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.state_path)


_ACTIVE: Optional[FaultPlan] = None
_RANK: int = 0


def set_rank(rank: int) -> None:
    """Declares this process's gang rank (``distributed.runtime`` calls
    it from ``init_runtime``); rank-scoped events compare against it."""
    global _RANK
    _RANK = int(rank)


def current_rank() -> int:
    return _RANK


def arm_plan(plan: Optional[FaultPlan]) -> None:
    """Installs ``plan`` process-wide (``None`` disarms)."""
    global _ACTIVE
    _ACTIVE = plan


def disarm() -> None:
    arm_plan(None)


def active() -> Optional[FaultPlan]:
    return _ACTIVE


@contextlib.contextmanager
def arm(plan: FaultPlan):
    """Context manager: arm ``plan`` for the enclosed block only."""
    prev = _ACTIVE
    arm_plan(plan)
    try:
        yield plan
    finally:
        arm_plan(prev)


# ------------------------------------------------------- hook sites ----

def on_train_step(step: int) -> None:
    """Called by the trainer before dispatching global step ``step``."""
    plan = _ACTIVE
    if plan is None:
        return
    for ev in plan.matching("slow_step"):
        if ev.step == step and ev._take():
            plan.persist_state()
            time.sleep(ev.delay_s)
    for ev in plan.matching("proc_kill"):
        if ev.step == step and ev._take():
            # a REAL worker death: persist the firing first (the
            # respawned process must not re-fire), then kill -9 self —
            # no exception handling, no atexit, no flushed buffers
            plan.persist_state()
            os.kill(os.getpid(), signal.SIGKILL)
    for ev in plan.matching("train_step"):
        if ev.step == step and ev._take():
            plan.persist_state()
            raise InjectedCrash(f"injected crash at train step {step}")


def on_shard_read(root: str, shard: int) -> None:
    """Called by the packed-shard reader before touching shard files —
    inside its retry loop, so a transient event (small ``times``) is
    absorbed by the retries while a persistent one (``times=None``)
    exhausts them."""
    plan = _ACTIVE
    if plan is None:
        return
    for ev in plan.matching("shard_read"):
        if (ev.shard is None or ev.shard == shard) and ev._take():
            plan.persist_state()
            raise IOError(
                f"injected transient IOError reading shard {shard} "
                f"of {root!r} (firing {ev.fired}"
                f"{'' if ev.times is None else f'/{ev.times}'})")


def on_ckpt_write(step: int) -> Optional[str]:
    """Called by ``ckpt.checkpoint.save``; returns a directive
    (``"torn"``) when this save should be sabotaged, else ``None``.
    The saver implements the directive and raises ``InjectedCrash``
    after registering the damaged checkpoint."""
    plan = _ACTIVE
    if plan is None:
        return None
    for ev in plan.matching("ckpt_write"):
        if (ev.at_save is None or ev.at_save == step) and ev._take():
            plan.persist_state()
            return ev.mode
    return None


def on_manifest_write(step: int) -> None:
    """Called by ``ckpt.coordinated`` on the committing rank after all
    rank payloads are durable, immediately before the step manifest
    commits — the window where a rank-0 death must leave the previous
    checkpoint authoritative.  A matching event kills the process."""
    plan = _ACTIVE
    if plan is None:
        return
    for ev in plan.matching("manifest_write"):
        if (ev.at_save is None or ev.at_save == step) and ev._take():
            plan.persist_state()
            os.kill(os.getpid(), signal.SIGKILL)
