"""Deterministic, seeded fault injection for the streaming trainer.

The crash-equivalence tests (tests/test_fault_tolerance.py) and the
supervised-restart benchmark need *reproducible* production failures:
a process crash at train step N, a transient ``IOError`` on a shard
read, a torn (partially-persisted) checkpoint write, an injected slow
step for the straggler watchdog.  This module is the single switch for
all of them:

  * a ``FaultPlan`` is an ordered list of ``FaultEvent``s, each naming
    a hook site and a trigger (step / shard / checkpoint step) plus how
    many times it fires (``times=None`` = persistent — the model for a
    genuinely corrupt disk block, as opposed to a transient hiccup);
  * ``arm(plan)`` installs the plan process-wide; hook points in
    ``train.streaming.fit_streaming``, ``data.hashed_dataset
    .load_packed_shard`` and ``ckpt.checkpoint.save`` consult it.
    Every call site guards on the module global first::

        if faults._ACTIVE is not None:
            faults.on_train_step(step)

    so the unarmed cost is one global load + identity check — zero
    overhead on the hot path when no plan is armed (the default);
  * firing counts live ON the plan (``FaultEvent.fired``), so one plan
    armed across a supervised restart loop injects its crash exactly
    ``times`` times and then lets the retries succeed — which is what
    makes the crash-equivalence property testable in-process.

Injected failures are ordinary exceptions: ``InjectedCrash`` (a
``RuntimeError`` — the supervisor treats it like any worker death) and
a plain ``IOError`` for shard reads (so the reader's bounded
retry-with-backoff path handles it exactly like a real transient I/O
error).  The torn-checkpoint event is special: the hook *returns a
directive* and ``ckpt.checkpoint.save`` implements the tear itself
(write, truncate the payload, complete the rename + manifest update,
then crash) — simulating the real-world failure where the rename is
durable but the data pages never hit disk.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import List, Optional

__all__ = [
    "FaultEvent", "FaultPlan", "InjectedCrash", "arm", "arm_plan",
    "disarm", "active", "on_train_step", "on_shard_read",
    "on_ckpt_write",
]


class InjectedCrash(RuntimeError):
    """A planned process-crash stand-in: raised out of a hook site and
    (in the supervised loop) handled exactly like a worker death."""


@dataclasses.dataclass
class FaultEvent:
    """One planned failure.

    ``site`` selects the hook:

      * ``"train_step"`` — raise ``InjectedCrash`` when the trainer's
        global step equals ``step``;
      * ``"slow_step"``  — sleep ``delay_s`` before that step runs (the
        straggler the ``StepWatchdog`` should flag);
      * ``"shard_read"`` — raise ``IOError`` from the packed-shard
        reader when it opens shard ``shard`` (``None`` = any shard);
      * ``"ckpt_write"`` — tear the checkpoint written at checkpoint
        step ``at_save`` (``None`` = the next save): the payload is
        truncated *after* the atomic rename completes, then
        ``InjectedCrash`` is raised.

    ``times`` bounds how often the event fires (``None`` = every match,
    the persistent-corruption model); ``fired`` counts firings.
    """
    site: str
    step: Optional[int] = None
    shard: Optional[int] = None
    at_save: Optional[int] = None
    times: Optional[int] = 1
    delay_s: float = 0.0
    mode: str = "torn"
    fired: int = 0

    def _take(self) -> bool:
        if self.times is not None and self.fired >= self.times:
            return False
        self.fired += 1
        return True


@dataclasses.dataclass
class FaultPlan:
    """An ordered set of planned failures, armed process-wide via
    ``arm``/``arm_plan``.  The plan is stateful: each event remembers
    how often it fired, so the same plan object armed across a
    supervised restart sequence injects each failure exactly as
    scripted."""
    events: List[FaultEvent]
    seed: int = 0

    def matching(self, site: str):
        return [e for e in self.events if e.site == site]


_ACTIVE: Optional[FaultPlan] = None


def arm_plan(plan: Optional[FaultPlan]) -> None:
    """Installs ``plan`` process-wide (``None`` disarms)."""
    global _ACTIVE
    _ACTIVE = plan


def disarm() -> None:
    arm_plan(None)


def active() -> Optional[FaultPlan]:
    return _ACTIVE


@contextlib.contextmanager
def arm(plan: FaultPlan):
    """Context manager: arm ``plan`` for the enclosed block only."""
    prev = _ACTIVE
    arm_plan(plan)
    try:
        yield plan
    finally:
        arm_plan(prev)


# ------------------------------------------------------- hook sites ----

def on_train_step(step: int) -> None:
    """Called by the trainer before dispatching global step ``step``."""
    plan = _ACTIVE
    if plan is None:
        return
    for ev in plan.matching("slow_step"):
        if ev.step == step and ev._take():
            time.sleep(ev.delay_s)
    for ev in plan.matching("train_step"):
        if ev.step == step and ev._take():
            raise InjectedCrash(f"injected crash at train step {step}")


def on_shard_read(root: str, shard: int) -> None:
    """Called by the packed-shard reader before touching shard files —
    inside its retry loop, so a transient event (small ``times``) is
    absorbed by the retries while a persistent one (``times=None``)
    exhausts them."""
    plan = _ACTIVE
    if plan is None:
        return
    for ev in plan.matching("shard_read"):
        if (ev.shard is None or ev.shard == shard) and ev._take():
            raise IOError(
                f"injected transient IOError reading shard {shard} "
                f"of {root!r} (firing {ev.fired}"
                f"{'' if ev.times is None else f'/{ev.times}'})")


def on_ckpt_write(step: int) -> Optional[str]:
    """Called by ``ckpt.checkpoint.save``; returns a directive
    (``"torn"``) when this save should be sabotaged, else ``None``.
    The saver implements the directive and raises ``InjectedCrash``
    after registering the damaged checkpoint."""
    plan = _ACTIVE
    if plan is None:
        return None
    for ev in plan.matching("ckpt_write"):
        if (ev.at_save is None or ev.at_save == step) and ev._take():
            return ev.mode
    return None
