"""Straggler & failure accounting for the training loop.

On a real multi-pod deployment the per-host agent exports step latencies
and the controller reschedules persistent stragglers.  This module is
that controller's logic, host-local and fully testable:

  * ``StepWatchdog`` tracks a rolling latency window; a step slower than
    ``threshold ×`` the rolling median is flagged; ``k`` consecutive
    flags escalate to a straggler verdict (callback → in production, a
    reschedule request; in the data path, a ``backup_of`` hedge on the
    slow host's shard — see data/loader.py).
  * ``FailureInjector`` provides deterministic fault injection for the
    restart tests (fail at step N exactly once).
"""
from __future__ import annotations

import collections
import statistics
import time
from typing import Callable, Deque, Optional


class StepWatchdog:
    def __init__(self, threshold: float = 3.0, window: int = 32,
                 escalate_after: int = 3,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.threshold = threshold
        self.window: Deque[float] = collections.deque(maxlen=window)
        self.escalate_after = escalate_after
        self.on_straggler = on_straggler
        self.consecutive_slow = 0
        self.flagged_steps = []
        self.escalations = []
        self._t0: Optional[float] = None

    def start_step(self) -> None:
        self._t0 = time.perf_counter()

    def end_step(self, step: int,
                 duration: Optional[float] = None) -> bool:
        """Records a step; returns True if flagged slow."""
        if duration is None:
            if self._t0 is None:
                raise RuntimeError("end_step without start_step/duration")
            duration = time.perf_counter() - self._t0
            self._t0 = None
        slow = False
        if len(self.window) >= 8:
            med = statistics.median(self.window)
            slow = duration > self.threshold * med
        self.window.append(duration)
        if slow:
            self.flagged_steps.append(step)
            self.consecutive_slow += 1
            if self.consecutive_slow >= self.escalate_after:
                self.escalations.append(step)
                self.consecutive_slow = 0
                if self.on_straggler:
                    self.on_straggler(step, duration)
        else:
            self.consecutive_slow = 0
        return slow


class FailureInjector:
    """Raises ``RuntimeError`` exactly once when step == fail_at."""

    def __init__(self, fail_at: Optional[int] = None):
        self.fail_at = fail_at
        self.fired = False

    def maybe_fail(self, step: int) -> None:
        if self.fail_at is not None and not self.fired \
                and step == self.fail_at:
            self.fired = True
            raise RuntimeError(f"injected failure at step {step}")
