"""Capped exponential backoff with deterministic jitter.

One definition shared by every retry loop in the repo — the supervised
restart policy (``train.supervisor``), the transient-shard-read retries
(``data.hashed_dataset``) and the ``ScoreClient`` 429/503 retry
(``serving.server``).  Jitter is a pure function of ``(seed, attempt)``
(``np.random.SeedSequence``), so retry timing is reproducible run to
run — a hard requirement for the deterministic fault-injection tests —
while still de-correlating real fleets (give each worker its own seed).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["BackoffPolicy"]


@dataclasses.dataclass(frozen=True)
class BackoffPolicy:
    """``delay_s(attempt)`` = min(cap, base·factor^attempt), jittered
    by ±``jitter_frac`` deterministically from ``(seed, attempt)``."""
    base_s: float = 0.05
    factor: float = 2.0
    cap_s: float = 2.0
    jitter_frac: float = 0.1
    seed: int = 0

    def delay_s(self, attempt: int) -> float:
        d = min(float(self.cap_s),
                float(self.base_s) * float(self.factor) ** int(attempt))
        if self.jitter_frac:
            u = np.random.default_rng(
                np.random.SeedSequence((int(self.seed),
                                        int(attempt)))).random()
            d *= 1.0 + float(self.jitter_frac) * (2.0 * u - 1.0)
        return d

    def for_rank(self, rank: int) -> "BackoffPolicy":
        """A copy whose jitter stream is de-correlated for gang rank
        ``rank`` (same base/factor/cap).

        A gang restart re-launches every worker at the same instant;
        if all ranks share one jitter stream their retries stay in
        lockstep and the thundering herd the jitter exists to break is
        reproduced exactly.  The per-rank seed is derived through
        ``SeedSequence`` (not ``seed + rank``) so neighbouring ranks
        get unrelated streams, deterministically per ``(seed, rank)``.
        """
        derived = int(np.random.SeedSequence(
            (int(self.seed), 0x5eed, int(rank))).generate_state(1)[0])
        return dataclasses.replace(self, seed=derived)
