"""Fault-tolerance substrate: deterministic fault injection
(``faults``), capped-exponential retry backoff (``retry``), and
straggler/step-latency accounting (``watchdog``).

Training-side consumers: ``train.streaming`` (hook sites + watchdog
wiring), ``train.supervisor`` (restart loop), ``ckpt.checkpoint``
(torn-write injection), ``data.hashed_dataset`` (transient shard-read
faults + bounded retry).  Serving reuses ``BackoffPolicy`` for the
ScoreClient's opt-in 429/503 retry.
"""
from repro.ft.faults import (
    FaultEvent, FaultPlan, InjectedCrash, active, arm, arm_plan,
    current_rank, disarm, set_rank,
)
from repro.ft.retry import BackoffPolicy
from repro.ft.watchdog import FailureInjector, StepWatchdog

__all__ = [
    "FaultEvent", "FaultPlan", "InjectedCrash", "active", "arm",
    "arm_plan", "disarm", "set_rank", "current_rank",
    "BackoffPolicy",
    "FailureInjector", "StepWatchdog",
]
