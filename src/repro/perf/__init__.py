"""Measured cost-model dispatch (docs/DESIGN.md §2).

``perf.choose(op, shape)`` is the single selection point for every
implementation choice in the repo; ``perf.calibrate`` populates the
measured :class:`CostTable` behind it.
"""
from repro.perf.cost_model import (
    BBIT_KERNEL_MAX_V,
    ENV_DISPATCH,
    ENV_PROFILE,
    OPS,
    CostTable,
    ProfileError,
    choose,
    clear_profile,
    device_fingerprint,
    dispatch_report,
    fingerprint_key,
    forced,
    get_model,
    maybe_load_profile,
    reset,
    set_profile,
    shape_bucket,
    suggest_lane_caps,
    suggest_row_buckets,
)

__all__ = [
    "BBIT_KERNEL_MAX_V", "ENV_DISPATCH", "ENV_PROFILE", "OPS",
    "CostTable", "ProfileError", "choose", "clear_profile",
    "device_fingerprint", "dispatch_report", "fingerprint_key", "forced",
    "get_model", "maybe_load_profile", "reset", "set_profile",
    "shape_bucket", "suggest_lane_caps", "suggest_row_buckets",
    "calibrate", "summarize",
]


# ``calibrate``/``summarize`` live in the submodule of the same name;
# importing it lazily keeps jax-heavy benchmark code off the critical
# import path.  The import machinery binds the *submodule* over the
# package attribute, so after the first resolution we pin the functions
# into globals() — otherwise perf.calibrate(...) would only work once.
def __getattr__(name):
    if name in ("calibrate", "summarize"):
        import importlib
        mod = importlib.import_module("repro.perf.calibrate")
        globals()["calibrate"] = mod.calibrate
        globals()["summarize"] = mod.summarize
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
