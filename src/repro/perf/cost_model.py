"""Measured cost-model dispatch (see docs/DESIGN.md §2).

Every implementation choice in the repo — fused Pallas encode vs tiled
XLA, packed logits kernel vs unpack-fallback, interpret vs compiled
Pallas, serving micro-batch row buckets — flows through one entry
point, :func:`choose`.  The selection order is

    explicit ``impl=`` argument
  > :func:`forced` context (calibration / tests)
  > ``REPRO_DISPATCH`` env var (``"op=impl,op=impl"``)
  > a loaded :class:`CostTable` profile (argmin of measured seconds)
  > the static heuristic that reproduces the repo's historical policy

with *eligibility* filtering applied before any of them: a forced or
profiled impl that the hardware/shape cannot run (b outside the pack
set, 2^b over the one-hot kernel ceiling, non-pow-2 OPH bins, compiled
Pallas off-TPU) is ignored rather than crashed into.  Without a
profile and without overrides every choice is bit-identical to the old
scattered ``jax.default_backend() == "tpu"`` checks — this module is
the only place in ``src/repro`` allowed to ask for the backend.

Profiles are versioned JSON keyed by a backend/device fingerprint
(:func:`device_fingerprint`); a mismatched or corrupt profile is
rejected (``ProfileError``) and dispatch degrades to the heuristics.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import Callable, Dict, Iterable, Mapping, Optional, Tuple

import jax

# One-hot contraction kernel ceiling: past this vocabulary size the
# (k, 2^b) one-hot intermediate stops paying for itself.  Historically
# lived in kernels/ops.py (which still re-exports it).
BBIT_KERNEL_MAX_V = 4096

SCHEMA_VERSION = 1
ENV_DISPATCH = "REPRO_DISPATCH"
ENV_PROFILE = "REPRO_PROFILE"


class ProfileError(ValueError):
    """Raised for corrupt, wrong-schema, or wrong-device profiles."""


# ---------------------------------------------------------------------------
# fingerprint + shape buckets


def device_fingerprint() -> Dict[str, object]:
    devs = jax.devices()
    return {
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "none",
        "device_count": len(devs),
        "jax": jax.__version__,
    }


def fingerprint_key(fp: Mapping[str, object]) -> str:
    """The part of the fingerprint a profile must match to be usable.
    (jax version is recorded for provenance but not enforced.)"""
    return (f"{fp.get('backend')}|{fp.get('device_kind')}"
            f"|{fp.get('device_count')}")


def _pow2_at_least(n: int) -> int:
    n = max(1, int(n))
    return 1 << (n - 1).bit_length()


# shape keys bucketed to the next power of two (data-dependent sizes);
# everything else (k, b, v, scheme, ...) is part of the bucket verbatim
_BUCKETED_KEYS = frozenset({"rows", "nnz", "width", "m"})


def shape_bucket(shape: Optional[Mapping[str, object]]) -> str:
    if not shape:
        return "-"
    parts = []
    for key in sorted(shape):
        val = shape[key]
        if key in _BUCKETED_KEYS:
            val = _pow2_at_least(int(val))
        parts.append(f"{key}={val}")
    return ",".join(parts)


# ---------------------------------------------------------------------------
# op registry


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


_PACK_BITS: Optional[Tuple[int, ...]] = None


def _pack_bits() -> Tuple[int, ...]:
    # lazy: repro.kernels imports repro.perf at module load, so the
    # reverse edge must wait until first use
    global _PACK_BITS
    if _PACK_BITS is None:
        from repro.kernels.fused_encode import PACK_BITS
        _PACK_BITS = tuple(PACK_BITS)
    return _PACK_BITS


def _oph_kernel_ok(shape: Mapping[str, object]) -> bool:
    # the OPH scatter-min kernel needs lane-aligned (pow-2) bins; the
    # jnp path covers arbitrary k
    if str(shape.get("scheme", "")).startswith("oph"):
        return _is_pow2(int(shape.get("k", 0)))
    return True


def _encode_eligible(shape) -> Tuple[str, ...]:
    return ("pallas", "xla") if _oph_kernel_ok(shape) else ("xla",)


def _encode_packed_eligible(shape) -> Tuple[str, ...]:
    ok = int(shape.get("b", 0)) in _pack_bits() and _oph_kernel_ok(shape)
    return ("pallas", "xla") if ok else ("xla",)


def _logits_eligible(shape) -> Tuple[str, ...]:
    ok = int(shape.get("v", 1 << 30)) <= BBIT_KERNEL_MAX_V
    return ("kernel", "gather") if ok else ("gather",)


def _logits_packed_eligible(shape) -> Tuple[str, ...]:
    b = int(shape.get("b", 0))
    v = int(shape.get("v", (1 << b) if b else (1 << 30)))
    ok = b in _pack_bits() and v <= BBIT_KERNEL_MAX_V
    return ("kernel", "unpack") if ok else ("unpack",)


def _pallas_mode_eligible(shape) -> Tuple[str, ...]:
    # Mosaic lowering only exists on TPU; everywhere else Pallas runs
    # in interpret mode
    if jax.default_backend() == "tpu":
        return ("compiled", "interpret")
    return ("interpret",)


def _tpu_first(kernel_impl: str, fallback_impl: str):
    def heuristic(shape, eligible) -> str:
        if jax.default_backend() == "tpu" and kernel_impl in eligible:
            return kernel_impl
        return fallback_impl
    return heuristic


def _capability_first(kernel_impl: str, fallback_impl: str):
    # ops-layer policy: backend-independent — direct kernel callers
    # (and their tests) exercise the Pallas path on every backend
    def heuristic(shape, eligible) -> str:
        return kernel_impl if kernel_impl in eligible else fallback_impl
    return heuristic


@dataclasses.dataclass(frozen=True)
class OpSpec:
    name: str
    impls: Tuple[str, ...]
    eligible: Callable[[Mapping[str, object]], Tuple[str, ...]]
    heuristic: Callable[[Mapping[str, object], Tuple[str, ...]], str]
    calibrated: bool = True


OPS: Dict[str, OpSpec] = {}


def _register(spec: OpSpec) -> None:
    OPS[spec.name] = spec


# scheme-level encode: codes (int) out
_register(OpSpec("encode", ("pallas", "xla"), _encode_eligible,
                 _tpu_first("pallas", "xla")))
# scheme-level fused encode→pack: packed bytes out
_register(OpSpec("encode_packed", ("pallas", "xla"),
                 _encode_packed_eligible, _tpu_first("pallas", "xla")))
# model-level logits over widened int codes
_register(OpSpec("logits", ("kernel", "gather"), _logits_eligible,
                 _tpu_first("kernel", "gather")))
# model-level logits straight off packed bytes
_register(OpSpec("logits_packed", ("kernel", "unpack"),
                 _logits_packed_eligible, _tpu_first("kernel", "unpack")))
# ops-layer bwd choices inside the custom_vjps (capability-first: the
# kernel runs everywhere, interpret off-TPU — unchanged historical
# behavior without a profile)
_register(OpSpec("logits_bwd", ("kernel", "ref"), _logits_eligible,
                 _capability_first("kernel", "ref"), calibrated=False))
_register(OpSpec("logits_packed_bwd", ("kernel", "unpack"),
                 _logits_packed_eligible,
                 _capability_first("kernel", "unpack"), calibrated=False))
# interpret vs compiled Pallas execution
_register(OpSpec("pallas_mode", ("compiled", "interpret"),
                 _pallas_mode_eligible,
                 _capability_first("compiled", "interpret"),
                 calibrated=False))
# retrieval candidate scoring: packed-popcount Hamming + top-k.  The
# Pallas arm is gated like the rest of the packed family: only
# byte-aligned b flows through the packed retrieval/serving hot paths,
# so XLA ``population_count`` covers every other shape
def _hamming_topk_eligible(shape) -> Tuple[str, ...]:
    ok = int(shape.get("b", 0)) in _pack_bits()
    return ("pallas", "xla") if ok else ("xla",)


_register(OpSpec("hamming_topk", ("pallas", "xla"),
                 _hamming_topk_eligible, _tpu_first("pallas", "xla")))
# serving fused encode→score dispatch: single impl — calibrated for
# its cost-per-row curve (micro-batch sizing), never a choice
_register(OpSpec("serve_score", ("fused",), lambda s: ("fused",),
                 lambda s, e: "fused"))


# ---------------------------------------------------------------------------
# CostTable


@dataclasses.dataclass
class CostTable:
    """Measured seconds per (op, impl, shape-bucket), device-keyed."""

    fingerprint: Dict[str, object]
    entries: Dict[str, float] = dataclasses.field(default_factory=dict)
    table_version: str = "uncalibrated"
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    @staticmethod
    def key(op: str, impl: str, bucket: str) -> str:
        return f"{op}|{impl}|{bucket}"

    def put(self, op: str, impl: str,
            shape: Optional[Mapping[str, object]], seconds: float) -> None:
        self.entries[self.key(op, impl, shape_bucket(shape))] = float(seconds)

    def lookup(self, op: str, impl: str,
               shape: Optional[Mapping[str, object]] = None,
               *, bucket: Optional[str] = None) -> Optional[float]:
        if bucket is None:
            bucket = shape_bucket(shape)
        return self.entries.get(self.key(op, impl, bucket))

    def matches_device(self) -> bool:
        return (fingerprint_key(self.fingerprint)
                == fingerprint_key(device_fingerprint()))

    def to_json(self) -> Dict[str, object]:
        return {
            "schema": SCHEMA_VERSION,
            "table_version": self.table_version,
            "fingerprint": self.fingerprint,
            "meta": self.meta,
            "entries": self.entries,
        }

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "CostTable":
        try:
            with open(path) as f:
                raw = json.load(f)
        except (OSError, ValueError) as e:
            raise ProfileError(f"unreadable profile {path!r}: {e}") from e
        if not isinstance(raw, dict) or raw.get("schema") != SCHEMA_VERSION:
            raise ProfileError(
                f"profile {path!r}: unsupported schema "
                f"{raw.get('schema') if isinstance(raw, dict) else type(raw)}")
        fp = raw.get("fingerprint")
        entries = raw.get("entries")
        if not isinstance(fp, dict) or not isinstance(entries, dict):
            raise ProfileError(f"profile {path!r}: malformed body")
        try:
            entries = {str(k): float(v) for k, v in entries.items()}
        except (TypeError, ValueError) as e:
            raise ProfileError(f"profile {path!r}: non-numeric entry: "
                               f"{e}") from e
        return cls(fingerprint=fp, entries=entries,
                   table_version=str(raw.get("table_version", "?")),
                   meta=dict(raw.get("meta") or {}))


# ---------------------------------------------------------------------------
# the model: choose + observability


def _parse_env_dispatch(raw: str) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for part in raw.split(","):
        part = part.strip()
        if not part:
            continue
        op, _, impl = part.partition("=")
        if op.strip() and impl.strip():
            out[op.strip()] = impl.strip()
    return out


class CostModel:
    """Process-wide dispatch state: loaded profile, forced pins,
    per-(op, bucket) decision log, hit/fallback counters."""

    def __init__(self, table: Optional[CostTable] = None):
        self.table = table
        self._lock = threading.Lock()
        self._forced: Dict[str, str] = {}
        self.counts = {"explicit": 0, "forced": 0, "env": 0,
                       "profile": 0, "heuristic": 0, "ineligible": 0}
        self.choices: Dict[str, str] = {}   # "op|bucket" -> impl

    # -- profile management -------------------------------------------------

    def set_table(self, table: Optional[CostTable],
                  *, strict: bool = True) -> None:
        if table is not None and not table.matches_device():
            if strict:
                raise ProfileError(
                    "profile fingerprint "
                    f"{fingerprint_key(table.fingerprint)!r} does not match "
                    f"this device {fingerprint_key(device_fingerprint())!r}")
            table = None
        with self._lock:
            self.table = table

    # -- selection ----------------------------------------------------------

    def choose(self, op: str,
               shape: Optional[Mapping[str, object]] = None,
               *, impl: Optional[str] = None) -> str:
        spec = OPS[op]
        shape = dict(shape or {})
        eligible = spec.eligible(shape)
        bucket = shape_bucket(shape)

        source = None
        picked: Optional[str] = None
        if impl is not None:
            if impl in eligible:
                source, picked = "explicit", impl
            else:
                with self._lock:
                    self.counts["ineligible"] += 1
        if picked is None:
            forced = self._forced.get(op)
            if forced is not None and forced in eligible:
                source, picked = "forced", forced
        if picked is None:
            env = os.environ.get(ENV_DISPATCH)
            if env:
                want = _parse_env_dispatch(env).get(op)
                if want is not None and want in eligible:
                    source, picked = "env", want
        if picked is None and spec.calibrated and len(eligible) > 1:
            table = self.table
            if table is not None:
                costs = {i: table.lookup(op, i, bucket=bucket)
                         for i in eligible}
                if all(c is not None for c in costs.values()):
                    source, picked = "profile", min(costs, key=costs.get)
        if picked is None:
            picked = spec.heuristic(shape, eligible)
            source = "heuristic"

        with self._lock:
            self.counts[source] = self.counts.get(source, 0) + 1
            self.choices[f"{op}|{bucket}"] = picked
        return picked

    # -- forcing (calibration + tests) --------------------------------------

    def force(self, pins: Mapping[str, str]) -> "_ForcedCtx":
        return _ForcedCtx(self, dict(pins))

    # -- observability ------------------------------------------------------

    def report(self) -> Dict[str, object]:
        with self._lock:
            table = self.table
            return {
                "table_version": (table.table_version if table is not None
                                  else None),
                "profile_loaded": table is not None,
                "fingerprint": fingerprint_key(device_fingerprint()),
                "hits": self.counts["profile"],
                "fallbacks": self.counts["heuristic"],
                "overrides": (self.counts["explicit"]
                              + self.counts["forced"] + self.counts["env"]),
                "ineligible_overrides": self.counts["ineligible"],
                "choices": dict(self.choices),
            }


class _ForcedCtx:
    def __init__(self, model: CostModel, pins: Dict[str, str]):
        self._model, self._pins, self._saved = model, pins, {}

    def __enter__(self):
        with self._model._lock:
            for op, impl in self._pins.items():
                if op not in OPS:
                    raise KeyError(f"unknown dispatch op {op!r}")
                self._saved[op] = self._model._forced.get(op)
                self._model._forced[op] = impl
        return self._model

    def __exit__(self, *exc):
        with self._model._lock:
            for op, prev in self._saved.items():
                if prev is None:
                    self._model._forced.pop(op, None)
                else:
                    self._model._forced[op] = prev
        return False


# ---------------------------------------------------------------------------
# module-level singleton

_MODEL_LOCK = threading.Lock()
_MODEL: Optional[CostModel] = None


def get_model() -> CostModel:
    global _MODEL
    if _MODEL is None:
        with _MODEL_LOCK:
            if _MODEL is None:
                model = CostModel()
                path = os.environ.get(ENV_PROFILE)
                if path:
                    try:
                        model.set_table(CostTable.load(path), strict=True)
                    except ProfileError as e:
                        import warnings
                        warnings.warn(f"ignoring {ENV_PROFILE}: {e}")
                _MODEL = model
    return _MODEL


def reset() -> None:
    """Drop all dispatch state (tests)."""
    global _MODEL
    with _MODEL_LOCK:
        _MODEL = None


def choose(op: str, shape: Optional[Mapping[str, object]] = None,
           *, impl: Optional[str] = None) -> str:
    return get_model().choose(op, shape, impl=impl)


def forced(**pins: str) -> _ForcedCtx:
    """Context manager pinning ops to impls, e.g.
    ``with perf.forced(logits="gather"): ...`` — the in-process analog
    of ``REPRO_DISPATCH`` (and what calibration uses to time each arm)."""
    return get_model().force(pins)


def dispatch_report() -> Dict[str, object]:
    return get_model().report()


def set_profile(table_or_path, *, strict: bool = True) -> Optional[CostTable]:
    """Install a profile (``CostTable`` or path).  ``strict`` raises on
    device-fingerprint mismatch; otherwise the profile is dropped and
    dispatch stays on the heuristics.  Returns the installed table."""
    model = get_model()
    table = (CostTable.load(table_or_path)
             if isinstance(table_or_path, str) else table_or_path)
    model.set_table(table, strict=strict)
    return model.table


def clear_profile() -> None:
    get_model().set_table(None)


def maybe_load_profile(path: Optional[str]) -> bool:
    """Best-effort profile install for launchers/benches: missing file,
    corrupt JSON, or wrong device ⇒ False and heuristic dispatch."""
    if not path or not os.path.exists(path):
        return False
    try:
        set_profile(path, strict=True)
    except ProfileError as e:
        import warnings
        warnings.warn(f"ignoring profile {path!r}: {e}")
        return False
    return True


# ---------------------------------------------------------------------------
# micro-batch sizing off the serve_score cost curve

# keep a smaller row bucket only when dispatching at it beats padding
# up to the next kept bucket by at least this margin — otherwise the
# bucket just costs an extra compiled shape
_ROW_BUCKET_MARGIN = 0.85

# a smaller drain cap must beat the bigger batch's cost-per-row by >10%
# to win: ties and measurement noise resolve to the LARGEST batch
# (bigger batches amortize per-dispatch overhead the curve can't see)
_LANE_CAP_TOLERANCE = 1.10


def _serve_curve(table: CostTable, base_shape: Dict[str, object],
                 candidates: Iterable[int]) -> Optional[Dict[int, float]]:
    curve = {}
    for rows in candidates:
        cost = table.lookup("serve_score", "fused",
                            dict(base_shape, rows=rows))
        if cost is None or cost <= 0:
            return None
        curve[rows] = cost
    return curve


def _pow2_candidates(max_batch: int) -> Tuple[int, ...]:
    out, r = [], 1
    top = _pow2_at_least(max_batch)
    while r <= top:
        out.append(r)
        r *= 2
    return tuple(out)


def suggest_row_buckets(
        k: int, b: int, scheme: str, max_batch: int,
        nnz_buckets: Iterable[int],
        table: Optional[CostTable] = None,
) -> Optional[Dict[int, Tuple[int, ...]]]:
    """Per-nnz-lane row buckets from the measured ``serve_score``
    cost-per-dispatch curve.  Buckets whose cost is within
    ``1 - _ROW_BUCKET_MARGIN`` of just padding up to the next size are
    pruned (fewer compiled shapes, bigger effective batches).  Returns
    None — caller keeps the static pow-2 grid — whenever the profile
    lacks full coverage."""
    table = table if table is not None else get_model().table
    if table is None or not table.matches_device():
        return None
    candidates = _pow2_candidates(max_batch)
    out: Dict[int, Tuple[int, ...]] = {}
    for m in nnz_buckets:
        base = {"k": k, "b": b, "scheme": scheme, "nnz": m}
        curve = _serve_curve(table, base, candidates)
        if curve is None:
            return None
        keep = [candidates[-1]]
        for rows in reversed(candidates[:-1]):
            if curve[rows] <= _ROW_BUCKET_MARGIN * curve[keep[0]]:
                keep.insert(0, rows)
        out[int(m)] = tuple(keep)
    return out


def suggest_lane_caps(
        k: int, b: int, scheme: str, max_batch: int,
        nnz_buckets: Iterable[int],
        table: Optional[CostTable] = None,
) -> Optional[Dict[int, int]]:
    """Throughput-optimal micro-batch per nnz lane: the LARGEST row
    bucket whose measured cost *per row* is within
    ``_LANE_CAP_TOLERANCE`` of the curve's best — noise and flat curves
    resolve to max batch.  None without full coverage."""
    table = table if table is not None else get_model().table
    if table is None or not table.matches_device():
        return None
    candidates = _pow2_candidates(max_batch)
    out: Dict[int, int] = {}
    for m in nnz_buckets:
        base = {"k": k, "b": b, "scheme": scheme, "nnz": m}
        curve = _serve_curve(table, base, candidates)
        if curve is None:
            return None
        best = min(curve[r] / r for r in candidates)
        out[int(m)] = max(r for r in candidates
                          if curve[r] / r <= best * _LANE_CAP_TOLERANCE)
    return out
