"""One-shot microbenchmark calibration for the dispatch cost model.

Protocol (docs/DESIGN.md §2.3): for every (op, shape) in a small
deterministic grid, each eligible implementation is pinned via
``perf.forced`` and timed through the *real* dispatch path — the same
wrappers production calls — with one untimed warmup call (compile time
excluded; steady-state is what dispatch predicts) followed by
median-of-``trials`` timed calls, each blocked to completion.  A
global wall-clock ``budget_s`` is enforced between measurements: when
it runs out the table is returned as-is, and :func:`cost_model.choose`
simply falls back to the static heuristic for any bucket that is
missing an arm — partial profiles are safe by construction.

Trial inputs are derived from a fixed seed so two calibration runs on
the same box produce comparable tables.
"""
from __future__ import annotations

import time
from typing import Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

from repro.perf import cost_model
from repro.perf.cost_model import OPS, CostTable, device_fingerprint


class _Budget:
    def __init__(self, budget_s: float):
        self._t0 = time.perf_counter()
        self._budget = float(budget_s)

    def spent(self) -> float:
        return time.perf_counter() - self._t0

    def exhausted(self) -> bool:
        return self.spent() >= self._budget


def _median_time(fn, trials: int, budget: _Budget) -> Optional[float]:
    """One warmup (compile) + up to ``trials`` timed calls; returns the
    median, or the single warmup-adjacent sample if the budget dies
    early, or None if there was no room for even the warmup."""
    if budget.exhausted():
        return None
    fn()                                    # warmup / compile — untimed
    samples = []
    for _ in range(max(1, trials)):
        if samples and budget.exhausted():
            break
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples)) if samples else None


def _block(x) -> None:
    import jax
    jax.block_until_ready(x)


# ---------------------------------------------------------------------------
# deterministic trial inputs


def _sparse_rows(rng: np.random.Generator, rows: int, width: int):
    import jax.numpy as jnp
    idx = rng.integers(0, 1 << 30, size=(rows, width)).astype(np.int32)
    nnz = rng.integers(max(1, width // 2), width + 1,
                       size=(rows,)).astype(np.int32)
    return jnp.asarray(idx), jnp.asarray(nnz)


def _measure_encode(table: CostTable, *, scheme: str, k: int, b: int,
                    rows: int, width: int, trials: int, budget: _Budget,
                    seed: int, packed: bool) -> None:
    from repro.core.schemes import make_scheme
    op = "encode_packed" if packed else "encode"
    sch = make_scheme(scheme, k, seed)
    rng = np.random.default_rng(seed * 7919 + rows * 31 + width)
    idx, nnz = _sparse_rows(rng, rows, width)
    shape = {"scheme": scheme, "k": k, "b": b, "rows": rows, "nnz": width}
    for impl in OPS[op].eligible(shape):
        with cost_model.forced(**{op: impl}):
            if packed:
                fn = lambda: _block(sch.encode_packed_device(idx, nnz, b))
            else:
                fn = lambda: _block(sch.encode_device(idx, nnz, b))
            sec = _median_time(fn, trials, budget)
        if sec is not None:
            table.put(op, impl, shape, sec)


def _measure_logits(table: CostTable, *, k: int, b: int, rows: int,
                    trials: int, budget: _Budget, seed: int,
                    packed: bool) -> None:
    import jax
    import jax.numpy as jnp
    from repro.core.bbit import pack_codes
    from repro.models.linear import (
        BBitLinearConfig, bbit_logits, bbit_logits_packed, init_bbit_linear)
    op = "logits_packed" if packed else "logits"
    v = 1 << b
    cfg = BBitLinearConfig(k=k, b=b)
    params = init_bbit_linear(cfg, jax.random.key(seed))
    rng = np.random.default_rng(seed * 104729 + rows * 13 + b)
    codes = rng.integers(0, v, size=(rows, k)).astype(np.uint16)
    shape = {"k": k, "b": b, "v": v, "rows": rows}
    if packed:
        x = jnp.asarray(pack_codes(codes, b))
    else:
        x = jnp.asarray(codes.astype(np.int32))
    for impl in OPS[op].eligible(shape):
        with cost_model.forced(**{op: impl}):
            # fresh jit wrapper per impl — the pin is read at trace time
            fn = jax.jit(
                (lambda p, c: bbit_logits_packed(p, c, cfg)) if packed
                else (lambda p, c: bbit_logits(p, c, cfg)))
            sec = _median_time(lambda: _block(fn(params, x)),
                               trials, budget)
        if sec is not None:
            table.put(op, impl, shape, sec)


def _measure_serve_score(table: CostTable, *, scheme: str, k: int, b: int,
                         max_batch: int, nnz_buckets: Sequence[int],
                         trials: int, budget: _Budget, seed: int) -> None:
    """Cost-per-dispatch curve for the serving fused encode→score path
    over the (row bucket × nnz lane) grid — feeds
    ``perf.suggest_row_buckets`` / ``suggest_lane_caps``."""
    import jax
    import jax.numpy as jnp
    from repro.core.schemes import make_scheme
    from repro.models.linear import (
        BBitLinearConfig, bbit_scores_packed, init_bbit_linear)
    sch = make_scheme(scheme, k, seed)
    cfg = BBitLinearConfig(k=k, b=b)
    params = init_bbit_linear(cfg, jax.random.key(seed))

    @jax.jit
    def score(idx, nnz, p):
        packed, empty = sch.encode_packed_jit(idx, nnz, b)
        return bbit_scores_packed(p, packed, cfg, empty_packed=empty)

    rng = np.random.default_rng(seed * 613 + k)
    for m in nnz_buckets:
        for rows in cost_model._pow2_candidates(max_batch):
            if budget.exhausted():
                return
            idx, nnz = _sparse_rows(rng, rows, int(m))
            sec = _median_time(lambda: _block(score(idx, nnz, params)),
                               trials, budget)
            if sec is not None:
                table.put("serve_score", "fused",
                          {"scheme": scheme, "k": k, "b": b,
                           "rows": rows, "nnz": int(m)}, sec)


# ---------------------------------------------------------------------------


def calibrate(*, k: int = 256, b_values: Iterable[int] = (8,),
              schemes: Iterable[str] = ("oph",),
              encode_rows: Iterable[int] = (64, 256),
              encode_widths: Iterable[int] = (256, 1024),
              logits_rows: Iterable[int] = (256, 1024),
              max_batch: int = 64,
              nnz_buckets: Sequence[int] = (128, 512, 2048),
              include_serving: bool = True,
              trials: int = 3, budget_s: float = 60.0,
              seed: int = 0,
              table_version: str = "v1") -> CostTable:
    """Populate a :class:`CostTable` for this device within a wall-clock
    budget.  Shapes are visited cheapest-first so a tight budget still
    yields complete (all-impl) entries for the small buckets."""
    budget = _Budget(budget_s)
    table = CostTable(fingerprint=device_fingerprint(),
                      table_version=table_version,
                      meta={"budget_s": float(budget_s),
                            "trials": int(trials), "seed": int(seed),
                            "k": int(k), "schemes": list(schemes),
                            "b_values": [int(b) for b in b_values]})
    for b in b_values:
        for rows in sorted(encode_rows):
            for width in sorted(encode_widths):
                for scheme in schemes:
                    if budget.exhausted():
                        break
                    _measure_encode(table, scheme=scheme, k=k, b=b,
                                    rows=rows, width=width, trials=trials,
                                    budget=budget, seed=seed, packed=True)
                    _measure_encode(table, scheme=scheme, k=k, b=b,
                                    rows=rows, width=width, trials=trials,
                                    budget=budget, seed=seed, packed=False)
        for rows in sorted(logits_rows):
            if budget.exhausted():
                break
            _measure_logits(table, k=k, b=b, rows=rows, trials=trials,
                            budget=budget, seed=seed, packed=True)
            _measure_logits(table, k=k, b=b, rows=rows, trials=trials,
                            budget=budget, seed=seed, packed=False)
        if include_serving and not budget.exhausted():
            for scheme in schemes:
                _measure_serve_score(table, scheme=scheme, k=k, b=b,
                                     max_batch=max_batch,
                                     nnz_buckets=nnz_buckets,
                                     trials=trials, budget=budget,
                                     seed=seed)
    table.meta["calibrate_seconds"] = round(budget.spent(), 3)
    table.meta["n_entries"] = len(table.entries)
    return table


def summarize(table: CostTable) -> Dict[str, object]:
    """Human-oriented digest: per-op entry counts and, for each op with
    both arms measured, which impl the profile would pick per bucket."""
    per_op: Dict[str, int] = {}
    picks: Dict[str, Dict[str, str]] = {}
    by_bucket: Dict[Tuple[str, str], Dict[str, float]] = {}
    for key, sec in table.entries.items():
        op, impl, bucket = key.split("|", 2)
        per_op[op] = per_op.get(op, 0) + 1
        by_bucket.setdefault((op, bucket), {})[impl] = sec
    for (op, bucket), costs in sorted(by_bucket.items()):
        if len(costs) > 1:
            picks.setdefault(op, {})[bucket] = min(costs, key=costs.get)
    return {"table_version": table.table_version,
            "fingerprint": table.fingerprint,
            "entries": len(table.entries), "per_op": per_op,
            "profile_picks": picks, "meta": table.meta}
