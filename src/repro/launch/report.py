"""Renders EXPERIMENTS.md §Dry-run/§Roofline tables from the artifacts.

Usage: PYTHONPATH=src python -m repro.launch.report [--art artifacts/dryrun]
Prints markdown to stdout (EXPERIMENTS.md embeds the output).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict


def load(art_dir: str):
    recs = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        if "__" not in os.path.basename(p):
            continue
        recs.append(json.load(open(p)))
    return recs


def _fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(recs, mesh: str) -> str:
    lines = [
        f"### Dry-run — {mesh} "
        f"({'512' if mesh == 'multi_pod' else '256'} chips)",
        "",
        "| arch | shape | status | compile s | resident GiB/dev | fits "
        "16 GiB | HLO colls |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | skipped (full attn @500k)"
                f" | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | ERROR | — | — | — | — |")
            continue
        m = r["memory"]
        resident = m.get("resident_bytes",
                         m.get("argument_bytes", 0)
                         + m.get("temp_bytes", 0))
        c = r.get("cost_full_hlo_once", {})
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | "
            f"{r.get('compile_seconds', 0):.0f} | "
            f"{_fmt_bytes(resident)} | "
            f"{'✓' if m.get('fits') else '✗'} | "
            f"{c.get('coll_count', 0)} |")
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = [
        "### Roofline — single-pod (16×16, 256 chips), per-device terms",
        "",
        "| arch | shape | compute s | memory s | collective s | dominant"
        " | bound s | frac | useful-FLOP ratio |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != "single_pod" or r.get("status") != "ok":
            continue
        rl = r.get("roofline")
        if not rl:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3g} | "
            f"{rl['memory_s']:.3g} | {rl['collective_s']:.3g} | "
            f"{rl['dominant']} | {rl['step_lower_bound_s']:.3g} | "
            f"{rl['roofline_fraction']:.3f} | "
            f"{rl.get('useful_flops_ratio', 0):.2f} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="artifacts/dryrun")
    args = ap.parse_args()
    recs = load(args.art)
    print(dryrun_table(recs, "single_pod"))
    print()
    print(dryrun_table(recs, "multi_pod"))
    print()
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
