"""Distributed step builders: jitted train/prefill/decode/linear steps
with explicit in/out shardings for any (arch × shape × mesh) cell.

Everything here works on abstract values (``jax.eval_shape``) so the
dry-run lowers trillion-parameter configs without allocating a byte;
the train/serve launchers call the same builders with real arrays.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.configs.rcv1_bbit import PaperConfig
from repro.launch.shapes import CellPlan
from repro.models.api import ModelAPI
from repro.models.linear import (
    BBitLinearConfig, bbit_logits, init_bbit_linear,
)
from repro.optim.optimizers import AdamWConfig, adamw
from repro.optim.quantized_state import moment_pspec
from repro.train.losses import mean_loss_fn
from repro.train.steps import TrainState


# ---------------------------------------------------------------------------
# pspec plumbing
# ---------------------------------------------------------------------------
def align_pspecs(tree: Any, pspec_tree: Any) -> Any:
    """Returns a pspec tree structurally matching ``tree``.

    Walks both trees; wherever the pspec tree lacks an entry (or rank
    mismatches), falls back to replication — robust against model/spec
    drift, which would otherwise fail deep inside pjit.
    """
    from repro.optim.quantized_state import QuantizedArray

    def walk(node, spec):
        if isinstance(node, dict):
            spec = spec if isinstance(spec, dict) else {}
            return {k: walk(v, spec.get(k)) for k, v in node.items()}
        if isinstance(node, TrainState):
            spec = spec if isinstance(spec, TrainState) \
                else TrainState(None, None, None)
            return TrainState(walk(node.params, spec.params),
                              walk(node.opt_state, spec.opt_state),
                              walk(node.step, spec.step))
        if isinstance(node, QuantizedArray):
            if isinstance(spec, QuantizedArray):
                return QuantizedArray(q=walk(node.q, spec.q),
                                      scale=walk(node.scale, spec.scale))
            return QuantizedArray(q=walk(node.q, None),
                                  scale=walk(node.scale, None))
        if isinstance(node, (list, tuple)):
            spec_seq = spec if isinstance(spec, (list, tuple)) \
                else [None] * len(node)
            out = [walk(v, s) for v, s in zip(node, spec_seq)]
            return type(node)(out)
        # array-like leaf
        shape = tuple(getattr(node, "shape", ()))
        rank = len(shape)
        if isinstance(spec, P):
            entries = tuple(spec)
            if len(entries) < rank:
                entries = entries + (None,) * (rank - len(entries))
            elif len(entries) > rank:
                entries = entries[:rank]
            return P(*_drop_indivisible(shape, entries))
        return P(*([None] * rank))

    return walk(tree, pspec_tree)


def _mesh_axis_sizes():
    """Axis sizes of the enclosing build's mesh (set by align callers)."""
    return _AXIS_SIZES.get("sizes", {})


_AXIS_SIZES: Dict[str, Dict[str, int]] = {}


def set_mesh_for_alignment(mesh: Mesh) -> None:
    _AXIS_SIZES["sizes"] = {a: int(mesh.shape[a]) for a in mesh.axis_names}


def _drop_indivisible(shape, entries):
    """Replace spec entries whose mesh-axis product doesn't divide the
    dim (odd vocabs, k=500, batch-1 caches, …) with replication."""
    sizes = _mesh_axis_sizes()
    out = []
    for dim, e in zip(shape, entries):
        if e is None:
            out.append(None)
            continue
        axes = (e,) if isinstance(e, str) else tuple(e)
        total = 1
        for a in axes:
            total *= sizes.get(a, 1)
        out.append(e if total and dim % total == 0 else None)
    return tuple(out)


def to_shardings(mesh: Mesh, pspec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspec_tree,
        is_leaf=lambda s: isinstance(s, P))


def batch_pspecs(mesh: Mesh, batch_shapes: Dict[str, Any]) -> Dict:
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    out = {}
    for k, v in batch_shapes.items():
        rank = len(v.shape)
        # batch-1 cells (long_500k) can't shard the batch dim
        lead = dp if v.shape[0] % max(dp_size, 1) == 0 else None
        out[k] = P(lead, *([None] * (rank - 1)))
    return out


# ---------------------------------------------------------------------------
# LM train step
# ---------------------------------------------------------------------------
def make_optimizer_for(cfg: ArchConfig):
    return adamw(3e-4, AdamWConfig(weight_decay=0.01, b2=0.95,
                                   moment_dtype=cfg.moment_dtype))


def abstract_train_state(api: ModelAPI) -> TrainState:
    opt = make_optimizer_for(api.cfg)

    def build():
        params = api.init_params(jax.random.key(0))
        return TrainState(params=params, opt_state=opt.init(params),
                          step=jnp.zeros((), jnp.int32))

    return jax.eval_shape(build)


def train_state_pspecs(api: ModelAPI, mesh: Mesh,
                       state_shapes: TrainState):
    pp = align_pspecs(state_shapes.params, api.param_pspecs(mesh))
    md = api.cfg.moment_dtype
    moments = jax.tree.map(
        lambda s: moment_pspec(s, md), pp,
        is_leaf=lambda s: isinstance(s, P))
    opt_ps = align_pspecs(state_shapes.opt_state,
                          {"m": moments, "v": moments})
    return TrainState(params=pp, opt_state=opt_ps, step=P())


def build_lm_train_step(api: ModelAPI, mesh: Mesh, plan: CellPlan):
    """Returns (jitted step, state_shapes, state_shardings, batch_specs)."""
    set_mesh_for_alignment(mesh)
    cfg = api.cfg
    opt = make_optimizer_for(cfg)
    n_micro = plan.n_micro
    accum_dtype = jnp.bfloat16 if cfg.moment_dtype == "int8" \
        else jnp.float32

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        def loss_of(params, mb):
            return api.loss_fn(params, mb, mesh)

        grad_fn = jax.value_and_grad(loss_of)
        if n_micro == 1:
            loss, grads = grad_fn(state.params, batch)
        else:
            def reshape(x):
                return x.reshape((n_micro, x.shape[0] // n_micro)
                                 + x.shape[1:])

            micro = jax.tree.map(reshape, batch)

            def body(carry, mb):
                gacc, lacc = carry
                l, g = grad_fn(state.params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), gacc, g)
                return (gacc, lacc + l), ()

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), state.params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
        new_params, new_opt = opt.update(grads, state.opt_state,
                                         state.params, state.step)
        return (TrainState(new_params, new_opt, state.step + 1), loss)

    state_shapes = abstract_train_state(api)
    state_ps = train_state_pspecs(api, mesh, state_shapes)
    bshapes = api.batch_shapes(plan.global_batch, plan.seq)
    bps = batch_pspecs(mesh, bshapes)
    jitted = jax.jit(
        train_step,
        in_shardings=(to_shardings(mesh, state_ps),
                      to_shardings(mesh, bps)),
        out_shardings=(to_shardings(mesh, state_ps),
                       NamedSharding(mesh, P())),
        donate_argnums=(0,),
    )
    return jitted, state_shapes, state_ps, bshapes, bps


# ---------------------------------------------------------------------------
# LM prefill / decode steps
# ---------------------------------------------------------------------------
def build_prefill_step(api: ModelAPI, mesh: Mesh, plan: CellPlan):
    set_mesh_for_alignment(mesh)
    cfg = api.cfg

    def prefill_step(params, batch):
        return api.prefill(params, batch, mesh)

    params_shapes = jax.eval_shape(
        lambda: api.init_params(jax.random.key(0)))
    pp = align_pspecs(params_shapes, api.param_pspecs(mesh))
    bshapes = api.batch_shapes(plan.global_batch, plan.seq)
    bshapes.pop("targets", None)
    bps = batch_pspecs(mesh, bshapes)
    jitted = jax.jit(
        prefill_step,
        in_shardings=(to_shardings(mesh, pp), to_shardings(mesh, bps)),
    )
    return jitted, params_shapes, pp, bshapes, bps


def build_decode_step(api: ModelAPI, mesh: Mesh, plan: CellPlan):
    set_mesh_for_alignment(mesh)
    cfg = api.cfg

    def decode_step(params, cache, cache_len, batch):
        return api.decode_step(params, batch, cache, cache_len, mesh)

    params_shapes = jax.eval_shape(
        lambda: api.init_params(jax.random.key(0)))
    pp = align_pspecs(params_shapes, api.param_pspecs(mesh))
    cache_shapes = jax.eval_shape(
        lambda: api.init_cache(plan.global_batch, plan.seq))
    cache_spec_tree = api.cache_pspecs(mesh) if api.cache_pspecs else None
    cps = align_pspecs(cache_shapes, cache_spec_tree)
    bshapes = api.decode_shapes(plan.global_batch)
    bps = batch_pspecs(mesh, bshapes)
    jitted = jax.jit(
        decode_step,
        in_shardings=(to_shardings(mesh, pp), to_shardings(mesh, cps),
                      NamedSharding(mesh, P()),
                      to_shardings(mesh, bps)),
        donate_argnums=(1,),
    )
    len_shape = jax.ShapeDtypeStruct((), jnp.int32)
    return jitted, (params_shapes, cache_shapes, len_shape, bshapes), \
        (pp, cps, P(), bps)


# ---------------------------------------------------------------------------
# the paper's linear model (rcv1_bbit) distributed train step
# ---------------------------------------------------------------------------
def build_linear_train_step(paper: PaperConfig, mesh: Mesh):
    """DP over examples, TP over the hashed table; logits psum'd."""
    set_mesh_for_alignment(mesh)
    lcfg = BBitLinearConfig(k=paper.k, b=paper.b,
                            n_classes=paper.n_classes,
                            use_kernel="never")
    opt = adamw(1e-2, AdamWConfig())
    loss_fn = mean_loss_fn(
        lambda p, c: bbit_logits(p, c, lcfg), paper.loss, l2=1e-7)

    def train_step(state: TrainState, codes, labels):
        loss, grads = jax.value_and_grad(loss_fn)(
            state.params, codes, labels)
        new_params, new_opt = opt.update(grads, state.opt_state,
                                         state.params, state.step)
        return TrainState(new_params, new_opt, state.step + 1), loss

    def build():
        params = init_bbit_linear(lcfg)
        return TrainState(params=params, opt_state=opt.init(params),
                          step=jnp.zeros((), jnp.int32))

    state_shapes = jax.eval_shape(build)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    param_ps = {"table": P(None, "model", None), "bias": P(None)}
    state_ps = TrainState(
        params=align_pspecs(state_shapes.params, param_ps),
        opt_state=align_pspecs(
            state_shapes.opt_state,
            {"m": param_ps, "v": param_ps}),
        step=P())
    codes_sds = jax.ShapeDtypeStruct(
        (paper.global_batch, paper.k), jnp.int32)
    labels_sds = jax.ShapeDtypeStruct((paper.global_batch,), jnp.int32)
    jitted = jax.jit(
        train_step,
        in_shardings=(to_shardings(mesh, state_ps),
                      NamedSharding(mesh, P(dp, None)),
                      NamedSharding(mesh, P(dp))),
        donate_argnums=(0,),
    )
    return jitted, state_shapes, state_ps, (codes_sds, labels_sds)
