"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model (assignment constants, TPU v5e):
    peak 197 TFLOP/s bf16 / chip, 819 GB/s HBM / chip, ~50 GB/s/link ICI.

Accounting method (see DESIGN.md §Roofline-accounting): XLA's
``cost_analysis()`` counts while-loop bodies ONCE (verified: a 10-step
scan of a matmul reports 1× the matmul FLOPs), so a full step compiled
with scan-over-layers + grad-accumulation under-reports by ~L·n_micro.
We therefore assemble costs from *probe* lowerings compiled with
``scan_layers=False`` at per-microbatch shapes on the real mesh:

    C_layer       = C(probe L=2) − C(probe L=1)        (per layer/group)
    C_embed_head  = C(probe L=1) − C_layer
    C_total_train = n_micro·(L_full·C_layer + C_embed_head) + C_opt
    C_opt         analytic (elementwise over N params; no collectives)

Every probe is a real compile on the production mesh, so its FLOPs,
bytes and collective schedule reflect partitioned, post-fusion HLO.
``cost_analysis()`` is per-device (verified); reported terms are
per-device seconds.  Collective wire bytes apply ring multipliers per
op from parsed replica group sizes.  sLSTM time-scan FLOPs (xlstm) are
added analytically (documented undercount otherwise).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

HW = dict(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)


# ---------------------------------------------------------------------------
# per-compile cost extraction
# ---------------------------------------------------------------------------
def wire_bytes(stats: List[dict]) -> float:
    """Per-participant ring-model wire bytes from collective stats."""
    total = 0.0
    for st in stats:
        r = float(st["bytes"])
        s = max(int(st.get("group_size") or 0), 1)
        op = st["op"]
        if op == "all-gather":
            total += r * (s - 1) / s
        elif op == "reduce-scatter":
            total += r * (s - 1)          # input = result × S
        elif op == "all-reduce":
            total += 2 * r * (s - 1) / s
        elif op == "all-to-all":
            total += r * (s - 1) / s
        else:                             # collective-permute
            total += r
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_count: int = 0

    def __add__(self, o):
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.coll_bytes + o.coll_bytes,
                    self.coll_count + o.coll_count)

    def __sub__(self, o):
        return Cost(self.flops - o.flops, self.bytes - o.bytes,
                    self.coll_bytes - o.coll_bytes,
                    self.coll_count - o.coll_count)

    def __mul__(self, k):
        return Cost(self.flops * k, self.bytes * k, self.coll_bytes * k,
                    int(self.coll_count * k))

    __rmul__ = __mul__

    def clamped(self):
        return Cost(max(self.flops, 0.0), max(self.bytes, 0.0),
                    max(self.coll_bytes, 0.0), max(self.coll_count, 0))

    def to_dict(self):
        return dataclasses.asdict(self)


def cost_of_compiled(compiled) -> Cost:
    from repro.distributed.collectives import collective_stats_from_hlo
    ca = compiled.cost_analysis() or {}
    stats = collective_stats_from_hlo(compiled.as_text())
    return Cost(
        flops=float(ca.get("flops", 0.0)),
        bytes=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=wire_bytes(stats),
        coll_count=len(stats),
    )


# ---------------------------------------------------------------------------
# analytic pieces
# ---------------------------------------------------------------------------
def optimizer_cost(n_params: int, n_devices: int, moment_dtype: str,
                   param_bytes: int = 2) -> Cost:
    """AdamW update, per-device share (params fully sharded)."""
    n = n_params / n_devices
    m_bytes = {"float32": 4, "bfloat16": 2, "int8": 1}[moment_dtype]
    # read g + p + m + v, write p + m + v  (+scales noise for int8)
    bytes_ = n * (param_bytes * 2 + 4 + (m_bytes * 2) * 2)
    return Cost(flops=14.0 * n, bytes=bytes_, coll_bytes=0.0)


def slstm_extra_flops(cfg, batch: int, seq: int, n_devices: int) -> float:
    """Recurrent sLSTM FLOPs that hide inside a time scan (train: ×3
    for fwd+bwd+remat-recompute)."""
    if cfg.family != "ssm":
        return 0.0
    groups = cfg.n_layers // cfg.slstm_every
    p = cfg.d_model // cfg.n_heads
    rec = 2 * cfg.n_heads * p * (4 * p)      # R·h per step
    return groups * batch * seq * rec / n_devices


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------
def roofline_terms(total: Cost, chips_per_pod_dim: Optional[int] = None
                   ) -> Dict[str, float]:
    compute_s = total.flops / HW["peak_flops"]
    memory_s = total.bytes / HW["hbm_bw"]
    # 2D torus: 4 links/chip usable; ring collectives stream over 2
    # links per direction pair — use 2 links effective per transfer.
    coll_s = total.coll_bytes / (2 * HW["ici_bw"])
    dominant = max(
        [("compute", compute_s), ("memory", memory_s),
         ("collective", coll_s)], key=lambda kv: kv[1])[0]
    bound = max(compute_s, memory_s, coll_s)
    return dict(compute_s=compute_s, memory_s=memory_s,
                collective_s=coll_s, dominant=dominant,
                step_lower_bound_s=bound,
                roofline_fraction=(compute_s / bound) if bound > 0 else 0.0)


def model_flops(cfg, batch: int, seq: int, kind: str) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode D = batch·1 token."""
    n = cfg.n_active_params() if cfg.is_moe else cfg.n_params()
    tokens = batch * (seq if kind != "decode" else 1)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
