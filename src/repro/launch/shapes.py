"""The assigned input-shape grid and per-cell execution policy."""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.configs.base import ArchConfig

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq=524288, global_batch=1),
}

ALL_SHAPES = tuple(SHAPES)


def cell_is_skipped(cfg: ArchConfig, shape: str) -> bool:
    """Assignment rule: long_500k only for sub-quadratic backbones."""
    return shape in cfg.skip_shapes


def local_batch(global_batch: int, dp: int) -> int:
    """Per-device batch; batch 1 cells keep 1 (seq shards instead)."""
    return max(1, global_batch // dp)


def choose_n_micro(cfg: ArchConfig, b_local: int, seq: int,
                   stash_budget_bytes: float = 4e9) -> int:
    """Gradient-accumulation depth: bound the per-device residual stash.

    With remat + scan-over-layers the dominant live activation is one
    (B_µ, S, d) residual per layer; pick the smallest n_micro dividing
    b_local that keeps L·B_µ·S·d·2 under the budget.  MoE archs get a
    tighter budget: the (E·C, d) dispatch buffers + gathered expert
    weights scale with per-microbatch tokens (granite at n_micro=1
    measured 25 GiB of MoE transients).
    """
    n_layers = cfg.n_layers + cfg.enc_layers
    if cfg.is_moe:
        stash_budget_bytes = min(stash_budget_bytes, 1.5e9)
    for n_micro in range(1, b_local + 1):
        if b_local % n_micro:
            continue
        stash = (n_layers * (b_local // n_micro) * seq
                 * cfg.d_model * 2)
        if stash <= stash_budget_bytes:
            return n_micro
    return b_local


@dataclasses.dataclass(frozen=True)
class CellPlan:
    arch: str
    shape: str
    kind: str
    seq: int
    global_batch: int
    n_micro: int
    b_local: int


def plan_cell(cfg: ArchConfig, shape: str, dp: int) -> CellPlan:
    info = SHAPES[shape]
    bl = local_batch(info["global_batch"], dp)
    n_micro = (choose_n_micro(cfg, bl, info["seq"])
               if info["kind"] == "train" else 1)
    return CellPlan(arch=cfg.name, shape=shape, kind=info["kind"],
                    seq=info["seq"], global_batch=info["global_batch"],
                    n_micro=n_micro, b_local=bl)
