"""Cost probes: small unrolled compiles whose differences yield exact
per-layer/per-group costs on the production mesh (see roofline.py).

Probe sets per family (train kind; prefill/decode analogous, fwd-only):

  dense/moe/vlm : L∈{1,2}                 → layer, embed+head
  hybrid        : L∈{every, 2·every}      → group (attn + every·mamba)
                  L∈{1, 2} (g=0, tail)    → mamba layer (for the tail)
  ssm (xlstm)   : L∈{every, 2·every}      → group ((every−1)·mL + 1·sL)
  audio         : (enc,dec)∈{(1,1),(2,1),(1,2)} → enc layer, dec layer

Each probe compiles with ``scan_layers=False`` so XLA's cost analysis
sees every op; multipliers then reconstruct the full stack.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch import steps as steps_lib
from repro.launch.roofline import (
    Cost, cost_of_compiled, optimizer_cost, slstm_extra_flops,
)
from repro.launch.shapes import CellPlan, plan_cell
from repro.models.api import get_model_api


def _probe_cfg(cfg: ArchConfig, seq: int = 0, **overrides) -> ArchConfig:
    # probes unroll layers AND attention blocks: XLA cost analysis sees
    # every op exactly once per real execution (triangular causal work).
    # ≥32k sequences use 4096² blocks: 36 unrolled blocks instead of 136
    # (compile minutes, not tens of minutes); the coarser causal
    # granularity overcounts attention-score FLOPs by ≤12.5%.
    if seq >= 32768:
        overrides.setdefault("attn_q_chunk", 4096)
        overrides.setdefault("attn_kv_chunk", 4096)
    return dataclasses.replace(cfg, scan_layers=False, attn_impl="loop",
                               **overrides)


def _micro_plan(plan: CellPlan) -> CellPlan:
    """The per-microbatch shape at which train probes run."""
    return dataclasses.replace(
        plan, global_batch=plan.global_batch // plan.n_micro, n_micro=1)


def _compile_probe(cfg: ArchConfig, mesh, plan: CellPlan) -> Cost:
    api = get_model_api(cfg)
    steps_lib.set_mesh_for_alignment(mesh)
    if plan.kind == "train":
        # loss+grad only (no optimizer — that's analytic)
        bshapes = api.batch_shapes(plan.global_batch, plan.seq)
        bps = steps_lib.batch_pspecs(mesh, bshapes)
        params_shapes = jax.eval_shape(
            lambda: api.init_params(jax.random.key(0)))
        pp = steps_lib.align_pspecs(params_shapes, api.param_pspecs(mesh))

        def fn(params, batch):
            return jax.value_and_grad(
                lambda p: api.loss_fn(p, batch, mesh))(params)

        jitted = jax.jit(
            fn,
            in_shardings=(steps_lib.to_shardings(mesh, pp),
                          steps_lib.to_shardings(mesh, bps)))
        with mesh:
            compiled = jitted.lower(params_shapes, bshapes).compile()
    elif plan.kind == "prefill":
        jitted, params_shapes, _, bshapes, _ = \
            steps_lib.build_prefill_step(api, mesh, plan)
        with mesh:
            compiled = jitted.lower(params_shapes, bshapes).compile()
    else:
        jitted, shapes_tuple, _ = steps_lib.build_decode_step(
            api, mesh, plan)
        with mesh:
            compiled = jitted.lower(*shapes_tuple).compile()
    return cost_of_compiled(compiled)


def _count_params(cfg: ArchConfig) -> int:
    api = get_model_api(cfg)
    shapes = jax.eval_shape(lambda: api.init_params(jax.random.key(0)))
    total = 0
    for leaf in jax.tree.leaves(shapes):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
    return total


def assemble_cell_cost(cfg: ArchConfig, shape: str, mesh,
                       plan: CellPlan) -> Tuple[Cost, Dict]:
    """Returns (total per-device Cost, probe detail dict)."""
    mp = _micro_plan(plan) if plan.kind == "train" else plan
    fam = cfg.family
    detail: Dict = {"kind": plan.kind, "n_micro": plan.n_micro}

    if fam in ("dense", "moe", "vlm"):
        c1 = _compile_probe(_probe_cfg(cfg, mp.seq, n_layers=1), mesh, mp)
        c2 = _compile_probe(_probe_cfg(cfg, mp.seq, n_layers=2), mesh, mp)
        layer = (c2 - c1).clamped()
        embed = (c1 - layer).clamped()
        total = cfg.n_layers * layer + embed
        detail.update(layer=layer.to_dict(), embed_head=embed.to_dict(),
                      multipliers={"layer": cfg.n_layers})
    elif fam == "hybrid":
        every = cfg.hybrid_attn_every
        groups = cfg.n_layers // every
        tail = cfg.n_layers - groups * every
        g1 = _compile_probe(_probe_cfg(cfg, mp.seq, n_layers=every), mesh, mp)
        g2 = _compile_probe(_probe_cfg(cfg, mp.seq, n_layers=2 * every), mesh, mp)
        group = (g2 - g1).clamped()
        embed = (g1 - group).clamped()
        total = groups * group + embed
        detail.update(group=group.to_dict(), embed_head=embed.to_dict(),
                      multipliers={"group": groups, "tail": tail})
        if tail:
            m1 = _compile_probe(_probe_cfg(cfg, mp.seq, n_layers=1), mesh, mp)
            m2 = _compile_probe(_probe_cfg(cfg, mp.seq, n_layers=2), mesh, mp)
            mamba_layer = (m2 - m1).clamped()
            total = total + tail * mamba_layer
            detail["mamba_layer"] = mamba_layer.to_dict()
    elif fam == "ssm":
        every = cfg.slstm_every
        g1 = _compile_probe(_probe_cfg(cfg, mp.seq, n_layers=every), mesh, mp)
        g2 = _compile_probe(_probe_cfg(cfg, mp.seq, n_layers=2 * every), mesh, mp)
        group = (g2 - g1).clamped()
        embed = (g1 - group).clamped()
        groups = cfg.n_layers // every
        total = groups * group + embed
        extra = slstm_extra_flops(cfg, mp.global_batch, mp.seq, mesh.size)
        if plan.kind == "train":
            extra *= 3.0       # fwd + bwd + remat recompute
        total = total + Cost(flops=extra)
        detail.update(group=group.to_dict(), embed_head=embed.to_dict(),
                      slstm_extra_flops=extra,
                      multipliers={"group": groups})
    elif fam == "audio":
        c11 = _compile_probe(_probe_cfg(cfg, mp.seq, n_layers=1, enc_layers=1),
                             mesh, mp)
        c21 = _compile_probe(_probe_cfg(cfg, mp.seq, n_layers=1, enc_layers=2),
                             mesh, mp)
        c12 = _compile_probe(_probe_cfg(cfg, mp.seq, n_layers=2, enc_layers=1),
                             mesh, mp)
        enc_layer = (c21 - c11).clamped()
        dec_layer = (c12 - c11).clamped()
        embed = (c11 - enc_layer - dec_layer).clamped()
        total = (cfg.enc_layers * enc_layer + cfg.n_layers * dec_layer
                 + embed)
        detail.update(enc_layer=enc_layer.to_dict(),
                      dec_layer=dec_layer.to_dict(),
                      embed_head=embed.to_dict(),
                      multipliers={"enc": cfg.enc_layers,
                                   "dec": cfg.n_layers})
    else:
        raise ValueError(fam)

    if plan.kind == "train":
        total = plan.n_micro * total
        opt = optimizer_cost(_count_params(cfg), mesh.size,
                             cfg.moment_dtype)
        total = total + opt
        detail["optimizer"] = opt.to_dict()
    return total, detail
