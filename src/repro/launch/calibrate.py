"""Calibration launcher — "calibrate once, run fast".

One-shot microbenchmark pass (``perf.calibrate``) over the dispatchable
ops on THIS box, writing a versioned, device-fingerprinted cost profile:

    python -m repro.launch.calibrate --out artifacts/perf/profile.json

Afterwards every launcher/bench that passes ``--profile`` (or reads
``CONFIG.profile_path`` / the ``REPRO_PROFILE`` env var) dispatches
encode, logits, and serving micro-batch sizing off the measured table
instead of the static platform heuristics.  The pass is wall-clock
budgeted (``--budget-s``) — a partial table is safe: any bucket missing
a measured arm just keeps the heuristic choice.
"""
from __future__ import annotations

import argparse
import json
import os


def main() -> None:
    from repro.configs.rcv1_oph import CONFIG
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=CONFIG.profile_path,
                    help="profile JSON destination")
    ap.add_argument("--budget-s", type=float,
                    default=CONFIG.calibrate_budget_s,
                    help="wall-clock budget for the whole pass")
    ap.add_argument("--trials", type=int, default=CONFIG.calibrate_trials)
    ap.add_argument("--k", type=int, default=CONFIG.k)
    ap.add_argument("--b", type=int, action="append", default=None,
                    help="b values to measure (repeatable; default "
                         f"[{CONFIG.b}])")
    ap.add_argument("--scheme", action="append", default=None,
                    help="schemes to measure (repeatable; default "
                         f"[{CONFIG.scheme!r}])")
    ap.add_argument("--max-batch", type=int,
                    default=CONFIG.calibrate_max_batch,
                    help="serving row-bucket ceiling for the "
                         "serve_score curve")
    ap.add_argument("--no-serving", action="store_true",
                    help="skip the serve_score sizing curve")
    ap.add_argument("--seed", type=int, default=CONFIG.seed)
    ap.add_argument("--table-version", default="v1")
    args = ap.parse_args()

    from repro import perf
    table = perf.calibrate(**CONFIG.calibrate_kwargs(
        k=args.k,
        b_values=tuple(args.b or [CONFIG.b]),
        schemes=tuple(args.scheme or [CONFIG.scheme]),
        max_batch=args.max_batch,
        include_serving=not args.no_serving,
        trials=args.trials, budget_s=args.budget_s, seed=args.seed,
        table_version=args.table_version))
    table.save(args.out)
    summary = perf.summarize(table)
    print(json.dumps(summary, indent=2, sort_keys=True))
    print(f"\nwrote {len(table.entries)} entries "
          f"({table.meta.get('calibrate_seconds', '?')}s) "
          f"-> {os.path.abspath(args.out)}")
    print("use it via --profile, CONFIG.profile_path, or "
          f"REPRO_PROFILE={args.out}")


if __name__ == "__main__":
    main()
