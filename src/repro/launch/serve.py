"""Serving launcher.

  --mode classifier : train a small hashed classifier, stand up the
                      dynamically-batched engine, then either replay a
                      request stream in-process (default; reports
                      throughput/latency/accuracy) or — with --http —
                      serve it over the network front end
                      (``serving.server.ScoreServer``: POST /score,
                      GET /status, POST /reload, graceful drain on
                      SIGTERM) until terminated.
  --mode lm         : greedy-generate from a reduced LM-zoo arch via
                      prefill + KV-cache decode (the serve_step the
                      decode dry-run cells lower at full scale).

HTTP flags (classifier mode): ``--http --host H --port P`` (port 0
picks an ephemeral port), ``--drain-timeout-s`` bounds how long SIGTERM
waits for in-flight requests, ``--adapt-every N`` re-derives the nnz
lane grid from live traffic every N requests.  ``--dedup-cache`` puts
the band-keyed duplicate-traffic score cache (``serving/dedup.py``) in
front of the batcher (``--cache-entries`` caps it) and prints one
``DEDUP_CACHE ...`` line alongside the ``LISTENING <host> <port>`` line
once the socket is bound (machine-readable; the e2e smoke and examples
wait on it).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _build_classifier_engine(args):
    import jax  # noqa: F401 — device runtime init before training
    from repro.data import (SynthRcv1Config, generate_arrays,
                            preprocess_rows)
    from repro.models.linear import BBitLinearConfig
    from repro.serving import HashedClassifierEngine
    from repro.train import train_bbit_liblinear

    cfg = SynthRcv1Config(seed=args.seed, topic_tokens=150,
                          background_frac=0.35,
                          max_pairs_per_doc=3000,
                          max_triples_per_doc=1500)
    rows, labels = generate_arrays(args.n_docs, cfg)
    codes = preprocess_rows(rows, k=args.k, b=args.b, seed=1, chunk=256)
    n_tr = args.n_docs * 2 // 3
    lcfg = BBitLinearConfig(k=args.k, b=args.b)
    res = train_bbit_liblinear(codes[:n_tr], labels[:n_tr],
                               codes[n_tr:], labels[n_tr:], lcfg,
                               loss="logistic", C=1.0, max_iter=25)
    print(f"model ready: test acc {res.test_acc:.3f}")
    from repro import perf
    from repro.configs.rcv1_oph import CONFIG
    profile = args.profile if args.profile is not None \
        else CONFIG.profile_path
    has_profile = perf.maybe_load_profile(profile)
    print("dispatch: "
          + (f"cost-model profile {profile}" if has_profile
             else "static heuristics (no usable profile)"))
    dedup_kw = {}
    if args.dedup_cache:
        dedup_kw = CONFIG.dedup_kwargs(dedup_cache=True,
                                       dedup_entries=args.cache_entries)
    eng = HashedClassifierEngine(
        res.params, lcfg, seed=1, max_batch=args.max_batch,
        nnz_buckets=(2048, 8192),
        # with a measured profile the engine derives per-lane row
        # buckets + drain caps from the serve_score cost curve;
        # without one this is the historical static pair
        row_buckets=None if has_profile else (1, args.max_batch),
        adapt_every=args.adapt_every, **dedup_kw)
    if args.dedup_cache:
        print(f"DEDUP_CACHE entries={args.cache_entries} "
              f"rows_per_band={CONFIG.dedup_rows_per_band} "
              f"probe_bands={CONFIG.dedup_probe_bands}", flush=True)
    else:
        print("DEDUP_CACHE off", flush=True)
    return eng, rows, labels, n_tr


def serve_classifier(args) -> None:
    eng, rows, labels, n_tr = _build_classifier_engine(args)
    if args.http:
        from repro.serving import ScoreServer
        srv = ScoreServer(
            eng, host=args.host, port=args.port,
            drain_timeout_s=args.drain_timeout_s,
            on_started=lambda s: (
                print(f"LISTENING {s.host} {s.port}", flush=True)))
        try:
            srv.run()                # blocks until SIGTERM/SIGINT
        finally:
            print(f"drained clean={srv.drained_clean} after "
                  f"{srv.http_requests} requests", flush=True)
        return
    eng.submit(rows[0]).result(timeout=300)   # first-request sanity
    t0 = time.perf_counter()
    futs = [eng.submit(rows[n_tr + i % (args.n_docs - n_tr)])
            for i in range(args.requests)]
    preds = np.array([f.result(timeout=300) for f in futs]) > 0
    dt = time.perf_counter() - t0
    want = np.array([labels[n_tr + i % (args.n_docs - n_tr)]
                     for i in range(args.requests)])
    print(f"{args.requests} requests in {dt:.2f}s "
          f"({args.requests/dt:.0f} req/s, "
          f"{eng.batcher.batches_run} batches), "
          f"accuracy {float(np.mean(preds == want)):.3f}")
    eng.close()


def serve_lm(args) -> None:
    import jax
    from repro.configs.base import get_config
    from repro.launch.smoke_configs import reduced_config
    from repro.models.api import get_model_api
    from repro.serving import greedy_generate

    cfg = reduced_config(get_config(args.arch))
    api = get_model_api(cfg)
    params = api.init_params(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    prompt = rng.integers(1, cfg.vocab, size=(args.max_batch, 8)
                          ).astype(np.int32)
    extras = {}
    shapes = api.batch_shapes(args.max_batch, 8)
    import jax.numpy as jnp
    for key in ("vision_embeds", "frames"):
        if key in shapes:
            extras[key] = jnp.zeros(shapes[key].shape, shapes[key].dtype)
    t0 = time.perf_counter()
    toks = greedy_generate(api, params, prompt, max_new=args.tokens,
                           max_len=8 + args.tokens, extras=extras or None)
    dt = time.perf_counter() - t0
    total_new = args.max_batch * args.tokens
    print(f"{args.arch} (reduced): generated {total_new} tokens in "
          f"{dt:.1f}s ({total_new/dt:.1f} tok/s incl. compile)")
    print("sample:", toks[0].tolist())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="classifier",
                    choices=["classifier", "lm"])
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--n-docs", type=int, default=600)
    ap.add_argument("--k", type=int, default=64)
    ap.add_argument("--b", type=int, default=8)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--http", action="store_true",
                    help="serve over HTTP instead of replaying a "
                         "request stream in-process")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8077,
                    help="0 picks an ephemeral port")
    ap.add_argument("--drain-timeout-s", type=float, default=30.0)
    ap.add_argument("--adapt-every", type=int, default=0,
                    help="re-derive nnz lane grid from live traffic "
                         "every N requests (0 = static grid)")
    ap.add_argument("--dedup-cache", action="store_true",
                    help="enable the band-keyed duplicate-traffic score "
                         "cache (serving/dedup.py) in front of the "
                         "batcher")
    ap.add_argument("--cache-entries", type=int, default=None,
                    help="dedup cache capacity (LRU entries; default: "
                         "the config's dedup_entries)")
    ap.add_argument("--profile", default=None,
                    help="perf cost-model profile JSON (default: the "
                         "config's profile_path if present) — drives "
                         "encode dispatch and micro-batch sizing")
    args = ap.parse_args()
    if args.cache_entries is None:
        from repro.configs.rcv1_oph import CONFIG
        args.cache_entries = CONFIG.dedup_entries
    if args.mode == "classifier":
        serve_classifier(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
