"""Reduced configs: same family/topology, laptop-scale dimensions.

Per the assignment, smoke tests instantiate a REDUCED config of each
arch family (few layers, small width, few experts, tiny vocab) and run
a real forward/train step on CPU; the FULL configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig


def reduced_config(cfg: ArchConfig) -> ArchConfig:
    kw = dict(
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) or 4,
        d_head=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        dtype="float32",
        attn_q_chunk=32,
        attn_kv_chunk=32,
        scan_layers=cfg.scan_layers,
        moment_dtype=cfg.moment_dtype,
    )
    if cfg.is_moe:
        kw.update(moe_experts=8, moe_top_k=2, moe_d_ff=64,
                  n_shared_experts=cfg.n_shared_experts)
    if cfg.rope_variant == "mrope":
        kw.update(mrope_sections=(2, 3, 3))
    if cfg.family == "hybrid":
        kw.update(n_layers=5, hybrid_attn_every=2,
                  hybrid_shared_attn_blocks=2, ssm_state=8,
                  ssm_head_dim=16, ssm_expand=2)
    if cfg.family == "ssm":
        kw.update(n_layers=6, slstm_every=3, ssm_expand=2, d_ff=0)
    if cfg.is_encdec:
        kw.update(enc_layers=2)
    if cfg.frontend != "none":
        kw.update(frontend_len=8)
    return dataclasses.replace(cfg, **kw)
