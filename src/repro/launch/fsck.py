"""Offline archive fsck: walk a hashed shard archive, verify CRCs.

``python -m repro.launch.fsck <archive_root>`` runs
``data.hashed_dataset.verify_shard`` over every shard — recomputing
each file's CRC32 against the ``meta.json`` record (format v4+) —
and prints one line per shard.  Corrupt shards are reported with the
exact mismatching files and land in the in-process
``quarantined_shards`` registry; ``--quarantine`` additionally moves
the bad shard's files aside on disk (``<name>.quarantined``) so a
subsequent training run fails fast on a missing shard instead of
training on silently rotten bytes.

Exit codes: 0 = every shard verified (or archive predates checksums —
reported, nothing to check), 1 = at least one corrupt shard, 2 = not
an archive.  This is the disk-side complement of the trainer's online
retry/quarantine story: run it from cron or before a long training
job, the same way you would fsck a filesystem you are about to trust.
"""
from __future__ import annotations

import argparse
import glob
import os
import sys

__all__ = ["fsck_archive", "main"]


def _shard_files(root: str, s: int) -> list:
    return sorted(glob.glob(os.path.join(root, f"hashed_{s:05d}.*")))


def _quarantine_files(root: str, s: int) -> list:
    moved = []
    for path in _shard_files(root, s):
        dst = path + ".quarantined"
        n = 1
        while os.path.exists(dst):
            dst = f"{path}.quarantined.{n}"
            n += 1
        os.rename(path, dst)
        moved.append(dst)
    return moved


def fsck_archive(root: str, *, quarantine: bool = False,
                 out=sys.stdout) -> dict:
    """Verifies every shard of the archive at ``root``; returns
    ``{"shards", "verified", "unchecked", "corrupt", "quarantined"}``
    where ``corrupt`` maps shard id → the error message."""
    from repro.data.hashed_dataset import (
        ShardCorruptionError, _read_meta, verify_shard,
    )

    meta = _read_meta(root)
    n_shards = int(meta.get("shards", 0))
    report = {"shards": n_shards, "verified": 0, "unchecked": 0,
              "corrupt": {}, "quarantined": {}}
    if not meta.get("shard_checksums"):
        print(f"{root}: format v{meta.get('format_version')} archive "
              "predates per-shard checksums — nothing to verify",
              file=out)
        report["unchecked"] = n_shards
        return report
    for s in range(n_shards):
        try:
            got = verify_shard(root, s, meta)
        except ShardCorruptionError as e:
            report["corrupt"][s] = str(e)
            print(f"shard {s:5d}: CORRUPT — {e}", file=out)
            if quarantine:
                moved = _quarantine_files(root, s)
                report["quarantined"][s] = moved
                print(f"shard {s:5d}: quarantined "
                      f"{len(moved)} file(s)", file=out)
            continue
        except (FileNotFoundError, OSError) as e:
            report["corrupt"][s] = f"unreadable: {e}"
            print(f"shard {s:5d}: UNREADABLE — {e}", file=out)
            continue
        if got is None:
            report["unchecked"] += 1
            print(f"shard {s:5d}: no recorded checksums", file=out)
        else:
            report["verified"] += 1
            print(f"shard {s:5d}: ok ({len(got)} files)", file=out)
    status = "CLEAN" if not report["corrupt"] else \
        f"{len(report['corrupt'])} CORRUPT"
    print(f"{root}: {report['verified']}/{n_shards} shards verified, "
          f"{report['unchecked']} unchecked — {status}", file=out)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.fsck",
        description="verify a hashed shard archive's recorded CRCs")
    ap.add_argument("root", help="archive directory (holds meta.json)")
    ap.add_argument("--quarantine", action="store_true",
                    help="move corrupt shards' files aside on disk")
    args = ap.parse_args(argv)
    if not os.path.exists(os.path.join(args.root, "meta.json")):
        print(f"{args.root}: not a hashed archive (no meta.json)",
              file=sys.stderr)
        return 2
    report = fsck_archive(args.root, quarantine=args.quarantine)
    return 1 if report["corrupt"] else 0


if __name__ == "__main__":
    sys.exit(main())
