"""Splices freshly-generated dry-run/roofline tables into EXPERIMENTS.md
between the BEGIN/END GENERATED markers.

Usage: PYTHONPATH=src python -m repro.launch.update_experiments
"""
from __future__ import annotations

import re

from repro.launch.report import load, dryrun_table, roofline_table


def main() -> None:
    recs = load("artifacts/dryrun")
    dr = (dryrun_table(recs, "single_pod") + "\n\n"
          + dryrun_table(recs, "multi_pod"))
    rl = roofline_table(recs)
    path = "EXPERIMENTS.md"
    text = open(path).read()
    text = re.sub(
        r"(<!-- BEGIN GENERATED DRYRUN TABLES[^\n]*-->).*?"
        r"(<!-- END GENERATED DRYRUN TABLES -->)",
        lambda m: m.group(1) + "\n" + dr + "\n" + m.group(2),
        text, flags=re.S)
    text = re.sub(
        r"(<!-- BEGIN GENERATED ROOFLINE TABLE -->).*?"
        r"(<!-- END GENERATED ROOFLINE TABLE -->)",
        lambda m: m.group(1) + "\n" + rl + "\n" + m.group(2),
        text, flags=re.S)
    open(path, "w").write(text)
    n_ok = sum(1 for r in recs if r.get("status") == "ok")
    n_skip = sum(1 for r in recs if r.get("status") == "skipped")
    n_err = sum(1 for r in recs if r.get("status") == "error")
    print(f"EXPERIMENTS.md updated: {n_ok} ok, {n_skip} skipped, "
          f"{n_err} error cells")


if __name__ == "__main__":
    main()
