import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell:
  1. build the production mesh (16×16 or 2×16×16 placeholder devices),
  2. build the cell's jitted step (train/prefill/decode) with the real
     shardings, lower it from ShapeDtypeStructs (no allocation),
  3. ``compile()`` — sharding mismatches / unsupported collectives fail
     here and are bugs in the system,
  4. record ``memory_analysis()`` (per-device fit proof),
     ``cost_analysis()`` and the HLO collective schedule,
  5. compile the roofline probes (scan_layers=False, L∈{1,2}) and
     assemble per-device roofline terms (launch/roofline.py).

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json, consumed by
launch/report.py to regenerate EXPERIMENTS.md tables.

Usage:
  python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both
  python -m repro.launch.dryrun --paper-linear
"""
import argparse
import dataclasses
import json
import time
import traceback


def _cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
          probes: bool = True, overrides: dict = None) -> dict:
    import jax
    from repro.configs.base import get_config
    from repro.launch import probes as probes_lib
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import (
        cost_of_compiled, model_flops, roofline_terms,
    )
    from repro.launch.shapes import SHAPES, cell_is_skipped, plan_cell
    from repro.models.api import get_model_api

    cfg = get_config(arch)
    if SHAPES[shape]["seq"] >= 32768:
        # long sequences: scan-based attention bounds live f32 score
        # buffers to one (q,kv) block (python-loop attention let XLA
        # keep every block's buffers alive — measured +26 GiB on the
        # deepseek-67b prefill_32k cell)
        cfg = dataclasses.replace(cfg, attn_impl="scan")
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh_name = "multi_pod" if multi_pod else "single_pod"
    rec = dict(arch=arch, shape=shape, mesh=mesh_name,
               overrides=overrides or {})
    if cell_is_skipped(cfg, shape):
        rec.update(status="skipped",
                   reason="full-attention arch; long_500k requires "
                          "sub-quadratic attention (DESIGN.md §5)")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    dp = 1
    for a in ("pod", "data"):
        dp *= mesh.shape.get(a, 1)
    plan = plan_cell(cfg, shape, dp)
    api = get_model_api(cfg)
    t0 = time.time()

    if plan.kind == "train":
        jitted, state_shapes, _, bshapes, _ = steps_lib.build_lm_train_step(
            api, mesh, plan)
        args = (state_shapes, bshapes)
    elif plan.kind == "prefill":
        jitted, params_shapes, _, bshapes, _ = steps_lib.build_prefill_step(
            api, mesh, plan)
        args = (params_shapes, bshapes)
    else:
        jitted, shapes_tuple, _ = steps_lib.build_decode_step(
            api, mesh, plan)
        args = shapes_tuple

    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    cost_once = cost_of_compiled(compiled)
    hbm_budget = 16 * 1024 ** 3
    peak = int(getattr(ma, "peak_memory_in_bytes", 0))
    args_b = int(getattr(ma, "argument_size_in_bytes", 0))
    temp_b = int(getattr(ma, "temp_size_in_bytes", 0))
    out_b = int(getattr(ma, "output_size_in_bytes", 0))
    resident = args_b + temp_b   # donated outputs alias arguments
    rec.update(
        status="ok",
        plan=dataclasses.asdict(plan),
        n_devices=n_dev,
        compile_seconds=round(t_compile, 1),
        memory=dict(peak_memory_bytes=peak,
                    argument_bytes=args_b,
                    temp_bytes=temp_b,
                    output_bytes=out_b,
                    resident_bytes=resident,
                    hbm_budget_bytes=hbm_budget,
                    fits=resident <= hbm_budget),
        cost_full_hlo_once=cost_once.to_dict(),
    )

    if probes:
        try:
            probe_total, detail = probes_lib.assemble_cell_cost(
                cfg, shape, mesh, plan)
            terms = roofline_terms(probe_total)
            mf = model_flops(cfg, plan.global_batch, plan.seq, plan.kind)
            mf_dev = mf / n_dev
            terms["model_flops_per_dev"] = mf_dev
            terms["hlo_flops_per_dev"] = probe_total.flops
            terms["useful_flops_ratio"] = (
                mf_dev / probe_total.flops if probe_total.flops else 0.0)
            rec["probe_cost"] = probe_total.to_dict()
            rec["probe_detail"] = detail
            rec["roofline"] = terms
        except Exception as e:  # noqa: BLE001 — record probe failures
            rec["probe_error"] = f"{type(e).__name__}: {e}"
            rec["probe_traceback"] = traceback.format_exc()[-2000:]
    return rec


def _paper_linear(multi_pod: bool) -> dict:
    import jax
    from repro.configs.rcv1_bbit import CONFIG as paper
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import cost_of_compiled, roofline_terms

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    jitted, state_shapes, _, (codes_sds, labels_sds) = \
        steps_lib.build_linear_train_step(paper, mesh)
    with mesh:
        compiled = jitted.lower(state_shapes, codes_sds,
                                labels_sds).compile()
    ma = compiled.memory_analysis()
    cost = cost_of_compiled(compiled)
    terms = roofline_terms(cost)
    return dict(
        arch="rcv1-bbit-linear", shape="train_batch65536",
        mesh="multi_pod" if multi_pod else "single_pod",
        status="ok", n_devices=mesh.size,
        compile_seconds=round(time.time() - t0, 1),
        memory=dict(
            peak_memory_bytes=int(getattr(ma, "peak_memory_in_bytes", 0)),
            argument_bytes=int(getattr(ma, "argument_size_in_bytes", 0)),
            temp_bytes=int(getattr(ma, "temp_size_in_bytes", 0)),
            fits=True),
        cost_full_hlo_once=cost.to_dict(),
        roofline=terms,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single_pod",
                    choices=["single_pod", "multi_pod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--paper-linear", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ArchConfig overrides (perf exps)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = (["single_pod", "multi_pod"] if args.mesh == "both"
              else [args.mesh])
    overrides = json.loads(args.override) if args.override else None

    jobs = []
    if args.paper_linear:
        for m in meshes:
            jobs.append(("paper", None, m))
    elif args.all:
        from repro.configs.archs import ALL_ARCHS
        from repro.launch.shapes import ALL_SHAPES
        for arch in ALL_ARCHS:
            for shape in ALL_SHAPES:
                for m in meshes:
                    jobs.append((arch, shape, m))
    else:
        for m in meshes:
            jobs.append((args.arch, args.shape, m))

    for arch, shape, m in jobs:
        multi = m == "multi_pod"
        if arch == "paper":
            rec = _paper_linear(multi)
            name = f"rcv1-bbit-linear__train__{m}{args.tag}.json"
        else:
            try:
                # roofline table is single-pod only (assignment);
                # multi-pod runs prove compile+memory without probes
                rec = _cell(arch, shape, multi, args.out,
                            probes=not args.no_probes and not multi,
                            overrides=overrides)
            except Exception as e:  # noqa: BLE001
                rec = dict(arch=arch, shape=shape, mesh=m,
                           status="error",
                           error=f"{type(e).__name__}: {e}",
                           traceback=traceback.format_exc()[-3000:])
            name = f"{arch}__{shape}__{m}{args.tag}.json"
        path = os.path.join(args.out, name)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec.get("status")
        mem = rec.get("memory", {})
        rl = rec.get("roofline", {})
        print(f"[{status}] {arch} × {shape} × {m}"
              f" resident={mem.get('resident_bytes', 0)/2**30:.2f}GiB"
              f" fits={mem.get('fits')}"
              f" dominant={rl.get('dominant')}"
              f" frac={rl.get('roofline_fraction', 0):.3f}"
              + (f" err={rec.get('error', rec.get('probe_error',''))[:120]}"
                 if status != "ok" or "probe_error" in rec else ""),
              flush=True)


if __name__ == "__main__":
    main()
