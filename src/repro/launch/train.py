"""Training launcher — the paper's end-to-end pipeline, production-shaped.

Three entry modes:

  * ``--mode linear`` (default; the paper's workload): synthetic
    expanded-rcv1 → one-time b-bit minwise hashing (cached on disk, the
    §6 economics) → distributed LR/SVM training with checkpoint/resume,
    failure injection, straggler watchdog, and optional b-bit gradient
    compression.
  * ``--mode stream``: the production path — ``fit_streaming`` over a
    sharded packed archive UNDER the supervised restart loop
    (``train.supervisor.run_supervised``): crashes restore from the
    newest valid checkpoint (torn/corrupt ones are quarantined) after a
    capped backoff, ``elastic`` folds the logical data-parallel world
    onto whatever devices are alive, and ``--fail-at`` injects a
    deterministic crash to watch it self-heal.
  * ``--mode lm``: trains a (reduced) LM-zoo arch on synthetic tokens
    through the same TrainState/checkpoint machinery (smoke-scale on
    CPU; the full configs are exercised by the dry-run).

Restart contract: the loader replays batches as a pure function of the
global step (streaming: of ``(seed, epoch, position)``), so kill →
relaunch produces bitwise-identical parameters (tested in
tests/test_checkpoint.py and tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import numpy as np


def run_linear(args) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.data import (
        SynthRcv1Config, generate_arrays, preprocess_and_save,
        load_hashed, HashedCodesLoader,
    )
    from repro.models.linear import (
        BBitLinearConfig, init_bbit_linear, bbit_logits, predict_classes,
    )
    from repro.optim.optimizers import make_optimizer
    from repro.train.losses import mean_loss_fn
    from repro.train.metrics import accuracy
    from repro.train.steps import init_state, build_train_step
    from repro.ckpt import checkpoint as ckpt
    from repro.ft.watchdog import StepWatchdog, FailureInjector

    hashed_dir = os.path.join(args.workdir, "hashed")
    if not os.path.exists(os.path.join(hashed_dir, "meta.json")):
        rows, labels = generate_arrays(
            args.n_docs, SynthRcv1Config(
                seed=args.seed, topic_tokens=150, background_frac=0.35,
                max_pairs_per_doc=8000, max_triples_per_doc=4000))
        stats = preprocess_and_save(hashed_dir, rows, labels,
                                    k=args.k, b=args.b, seed=args.seed,
                                    n_shards=4)
        print(f"preprocessed {stats['n']} docs in "
              f"{stats['seconds_hashing']:.1f}s (one-time cost)")
    codes, labels, meta = load_hashed(hashed_dir)
    n_test = len(labels) // 4
    codes_tr, y_tr = codes[:-n_test], labels[:-n_test]
    codes_te, y_te = codes[-n_test:], labels[-n_test:]

    lcfg = BBitLinearConfig(k=meta["k"], b=meta["b"])
    opt = make_optimizer("adamw", args.lr)
    loss_fn = mean_loss_fn(lambda p, c: bbit_logits(p, c, lcfg),
                           "logistic", l2=1e-6)
    step_fn = build_train_step(loss_fn, opt)
    loader = HashedCodesLoader(codes_tr, y_tr, args.batch_size,
                               seed=args.seed)

    ckpt_dir = os.path.join(args.workdir, "ckpt")
    state = init_state(init_bbit_linear(lcfg, jax.random.key(args.seed)),
                       opt)
    start_step = 0
    restored = ckpt.restore_if_exists(ckpt_dir, state)
    if restored is not None:
        state, start_step = restored
        print(f"resumed from step {start_step}")

    watchdog = StepWatchdog()
    injector = FailureInjector(args.fail_at)
    total_steps = args.steps
    losses = []
    for step, bc, by in loader.batches(start_step=start_step):
        if step >= total_steps:
            break
        injector.maybe_fail(step)
        watchdog.start_step()
        state, loss = step_fn(state, jnp.asarray(bc.astype(np.int32)),
                              jnp.asarray(by))
        watchdog.end_step(step)
        losses.append(float(loss))
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, state)
    ckpt.save(ckpt_dir, min(total_steps, step + 1), state)

    te_acc = accuracy(
        predict_classes(state.params, jnp.asarray(codes_te.astype(np.int32)),
                        lcfg), y_te)
    from repro import perf
    rep = perf.dispatch_report()
    print(f"final loss={np.mean(losses[-10:]):.4f} test_acc={te_acc:.4f} "
          f"stragglers={len(watchdog.flagged_steps)} "
          f"dispatch_hits={rep['hits']} fallbacks={rep['fallbacks']}")
    return dict(test_acc=te_acc, final_loss=float(np.mean(losses[-10:])),
                steps=int(min(total_steps, step + 1)))


def run_stream(args) -> dict:
    """Supervised streaming training over a sharded packed archive:
    crash-safe checkpoints, quarantine-checked restore, elastic device
    folding, straggler watchdog — the single-host production loop.
    ``--procs N`` upgrades it to an N-process ``jax.distributed`` gang
    under gang-restart supervision (coordinated checkpoints, respawn
    from the latest committed step on any worker death)."""
    from repro.configs.rcv1_oph import CONFIG
    from repro.data import (SynthRcv1Config, generate_arrays,
                            preprocess_and_save, shard_row_counts)
    from repro.ft import FaultEvent, FaultPlan, StepWatchdog, faults
    from repro.models.linear import BBitLinearConfig
    from repro.train import run_supervised

    hashed_dir = os.path.join(args.workdir, "shards")
    if not os.path.exists(os.path.join(hashed_dir, "meta.json")):
        rows, labels = generate_arrays(
            args.n_docs, SynthRcv1Config(
                seed=args.seed, topic_tokens=150, background_frac=0.35,
                max_pairs_per_doc=8000, max_triples_per_doc=4000))
        stats = preprocess_and_save(hashed_dir, rows, labels,
                                    k=args.k, b=args.b, seed=args.seed,
                                    n_shards=4)
        print(f"preprocessed {stats['n']} docs into 4 shards in "
              f"{stats['seconds_hashing']:.1f}s (one-time cost)")

    if args.procs and args.procs > 1:
        from repro.train.supervisor import run_multiprocess_supervised
        fault_spec = None
        if args.fail_at is not None:
            fault_spec = FaultPlan([
                FaultEvent(site="proc_kill", step=args.fail_at,
                           rank=args.procs - 1, times=1)]).to_spec()
        run = run_multiprocess_supervised(
            hashed_dir, BBitLinearConfig(k=args.k, b=args.b),
            procs=args.procs,
            run_dir=os.path.join(args.workdir, "gang"),
            policy=CONFIG.restart_policy(),
            fault_spec=fault_spec,
            local_devices=args.local_devices,
            ckpt_dir=os.path.join(args.workdir, "ckpt_stream"),
            seed=args.seed,
            **CONFIG.stream_kwargs(
                epochs=args.epochs, batch_size=args.batch_size,
                lr=args.lr, ckpt_every_shards=1,
                data_parallel=args.data_parallel or args.procs))
        rec = run.result
        print(f"gang of {args.procs} procs streamed "
              f"{rec['examples_seen']} rows x {args.epochs} epochs in "
              f"{rec['train_seconds']:.1f}s: progressive_acc="
              f"{rec['progressive_acc']:.4f} steps={rec['n_steps']} "
              f"gang_restarts={run.restarts} "
              f"topology={rec['lineage']}")
        return dict(progressive_acc=rec["progressive_acc"],
                    steps=rec["n_steps"], restarts=run.restarts,
                    crashes=[c.error for c in run.crashes])

    if args.fail_at is not None:
        faults.arm_plan(FaultPlan([
            FaultEvent(site="train_step", step=args.fail_at, times=1)]))
    watchdog = StepWatchdog()
    sup = run_supervised(
        hashed_dir, BBitLinearConfig(k=args.k, b=args.b),
        policy=CONFIG.restart_policy(), watchdog=watchdog,
        ckpt_dir=os.path.join(args.workdir, "ckpt_stream"),
        seed=args.seed,
        **CONFIG.stream_kwargs(epochs=args.epochs,
                               batch_size=args.batch_size, lr=args.lr,
                               ckpt_every_shards=1,
                               data_parallel=args.data_parallel))
    faults.disarm()
    res = sup.result
    n_rows = sum(shard_row_counts(hashed_dir))
    from repro import perf
    rep = perf.dispatch_report()
    print(f"streamed {n_rows} rows x {args.epochs} epochs in "
          f"{res.train_seconds:.1f}s: progressive_acc="
          f"{res.progressive_acc:.4f} steps={res.n_steps} "
          f"restarts={sup.restarts} "
          f"stragglers={sup.straggler_escalations} "
          f"topology={res.topology_lineage} "
          f"dispatch={res.dispatch} "
          f"(profile_hits={rep['hits']} fallbacks={rep['fallbacks']})")
    return dict(progressive_acc=res.progressive_acc,
                steps=res.n_steps, restarts=sup.restarts,
                crashes=[c.error for c in sup.crashes])


def run_lm(args) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_config
    from repro.data.lm_synth import lm_example_stream
    from repro.launch.smoke_configs import reduced_config
    from repro.models.api import get_model_api
    from repro.launch.steps import make_optimizer_for
    from repro.train.steps import TrainState
    from repro.ckpt import checkpoint as ckpt

    cfg = reduced_config(get_config(args.arch))
    api = get_model_api(cfg)
    opt = make_optimizer_for(cfg)
    params = api.init_params(jax.random.key(args.seed))
    state = TrainState(params=params, opt_state=opt.init(params),
                       step=jnp.zeros((), jnp.int32))

    @jax.jit
    def step_fn(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss_fn(p, batch, None))(state.params)
        new_p, new_o = opt.update(grads, state.opt_state, state.params,
                                  state.step)
        return TrainState(new_p, new_o, state.step + 1), loss

    ckpt_dir = os.path.join(args.workdir, f"ckpt_{args.arch}")
    start_step = 0
    restored = ckpt.restore_if_exists(ckpt_dir, state)
    if restored is not None:
        state, start_step = restored

    losses = []
    for step, toks, tgts in lm_example_stream(
            args.batch_size, args.seq_len, cfg.vocab, seed=args.seed):
        if step < start_step:
            continue
        if step >= args.steps:
            break
        batch = {"tokens": jnp.asarray(toks), "targets": jnp.asarray(tgts)}
        shapes = api.batch_shapes(args.batch_size, args.seq_len)
        if "vision_embeds" in shapes:
            batch["vision_embeds"] = jnp.zeros(
                shapes["vision_embeds"].shape, shapes["vision_embeds"].dtype)
        if "frames" in shapes:
            batch["frames"] = jnp.zeros(
                shapes["frames"].shape, shapes["frames"].dtype)
        state, loss = step_fn(state, batch)
        losses.append(float(loss))
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, state)
    first, last = losses[0], float(np.mean(losses[-5:]))
    print(f"{args.arch}: loss {first:.3f} -> {last:.3f} "
          f"over {len(losses)} steps")
    return dict(first_loss=first, last_loss=last)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="linear",
                    choices=["linear", "stream", "lm"])
    ap.add_argument("--workdir", default="artifacts/train")
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--n-docs", type=int, default=2000)
    ap.add_argument("--k", type=int, default=200)
    ap.add_argument("--b", type=int, default=8)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=128)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (FT testing)")
    ap.add_argument("--epochs", type=int, default=1,
                    help="stream mode: passes over the archive")
    ap.add_argument("--data-parallel", type=int, default=None,
                    help="stream mode: logical data-parallel world "
                         "(elastic — folds onto available devices)")
    ap.add_argument("--procs", type=int, default=None,
                    help="stream mode: launch an N-process "
                         "jax.distributed gang (localhost) under "
                         "gang-restart supervision")
    ap.add_argument("--local-devices", type=int, default=1,
                    help="stream mode with --procs: fake CPU devices "
                         "per gang worker")
    ap.add_argument("--profile", default=None,
                    help="perf cost-model profile JSON (default: the "
                         "config's profile_path if it exists; missing/"
                         "mismatched files fall back to the static "
                         "dispatch heuristics)")
    args = ap.parse_args()
    os.makedirs(args.workdir, exist_ok=True)
    from repro import perf
    from repro.configs.rcv1_oph import CONFIG
    profile = args.profile if args.profile is not None \
        else CONFIG.profile_path
    if perf.maybe_load_profile(profile):
        print(f"dispatch: cost-model profile {profile} "
              f"(table {perf.get_model().table.table_version})")
    else:
        print("dispatch: static heuristics (no usable profile; run "
              "python -m repro.launch.calibrate to measure this box)")
    if args.mode == "linear":
        run_linear(args)
    elif args.mode == "stream":
        run_stream(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
