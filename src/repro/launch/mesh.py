"""Production mesh builders (assignment-specified topology).

Functions, not module-level constants: importing this module never
touches jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
jax import; tests and benches see the real single device).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:                                   # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                    # older jax: Auto is the only mode
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 single pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(data: int = 4, model: int = 2) -> Mesh:
    """Small mesh for unit tests (requires ≥ data·model fake devices)."""
    return _make_mesh((data, model), ("data", "model"))


def _make_1d_mesh(axis: str, n_devices=None) -> Mesh:
    """1-D mesh over the first ``n_devices`` visible devices (all by
    default).  Unlike ``jax.make_mesh`` this accepts a device count
    below the total, so a 2-way run works on an 8-fake-device test
    process."""
    import numpy as np

    avail = jax.devices()
    n = len(avail) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(avail):
        raise ValueError(
            f"{axis} mesh needs 1 <= n_devices <= {len(avail)} visible "
            f"devices, got {n} (set XLA_FLAGS="
            "--xla_force_host_platform_device_count=N for fake devices)")
    devs = np.asarray(avail[:n])
    if AxisType is not None:
        return Mesh(devs, (axis,), axis_types=(AxisType.Auto,))
    return Mesh(devs, (axis,))


def make_data_mesh(n_devices=None) -> Mesh:
    """1-D ``("data",)`` mesh — the data-parallel streaming topology
    (``train.data_parallel``): batches shard over the axis, parameters
    replicate, gradients all-reduce with ``psum_mean``."""
    return _make_1d_mesh("data", n_devices)


def make_replica_mesh(n_replicas=None) -> Mesh:
    """1-D ``("replica",)`` mesh — the serving replica topology
    (``serving.engine.HashedClassifierEngine(replicas=N)``): the model
    is device_put ONCE per replica and bucket lanes round-robin their
    micro-batches across the axis; no collectives, throughput scales
    with independent devices."""
    return _make_1d_mesh("replica", n_replicas)
