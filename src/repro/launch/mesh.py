"""Production mesh builders (assignment-specified topology).

Functions, not module-level constants: importing this module never
touches jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
jax import; tests and benches see the real single device).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:                                   # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                    # older jax: Auto is the only mode
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 single pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(data: int = 4, model: int = 2) -> Mesh:
    """Small mesh for unit tests (requires ≥ data·model fake devices)."""
    return _make_mesh((data, model), ("data", "model"))
