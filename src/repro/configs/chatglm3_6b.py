"""chatglm3-6b — partial (2d-derived) RoPE, GQA [arXiv:2406.12793; hf].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.  ChatGLM applies
rotary embedding to half the head dims (partial rotary factor 0.5) —
the 'RoPE 2d' lineage of GLM — implemented as rope_variant='partial'.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_head=128,
    d_ff=13696,
    vocab=65024,
    rope_variant="partial",
    skip_shapes=("long_500k",),
))
