"""Architecture config schema + registry (``--arch <id>`` selection)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0              # 0 → d_model // n_heads

    # -- MoE ----------------------------------------------------------------
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_capacity: float = 1.25
    n_shared_experts: int = 0
    # serving-path MoE dispatch: 'psum' (baseline; FSDP weights gathered
    # per step) | 'weight_stationary' (experts 2D-sharded over
    # data×model, tokens all_to_all'd — §Perf)
    moe_serving_dispatch: str = "psum"
    moe_pad_to: int = 16             # expert-count padding multiple

    # -- position encoding ----------------------------------------------------
    rope_variant: str = "standard"   # standard | partial | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)

    # -- SSM / hybrid ---------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    hybrid_attn_every: int = 6       # zamba2: shared attn block cadence
    hybrid_shared_attn_blocks: int = 2
    slstm_every: int = 6             # xlstm: sLSTM cadence (rest mLSTM)

    # -- encoder-decoder ------------------------------------------------------
    enc_layers: int = 0              # >0 → enc-dec (audio/vlm encoders)

    # -- modality frontend (STUB: precomputed embeddings enter directly) -----
    frontend: str = "none"           # none | vision_stub | audio_stub
    frontend_len: int = 0            # frames/patches per example

    # -- embeddings -----------------------------------------------------------
    embedding: str = "dense"         # dense | bbit_hash (paper technique)
    hash_k: int = 8
    hash_b: int = 12

    # -- numerics / execution -------------------------------------------------
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    remat: bool = True
    remat_policy: str = "full"       # full | dots (save matmul outputs)
    scan_layers: bool = True
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    attn_impl: str = "loop"          # loop (exact FLOP probes) | scan
    # replicate KV heads up to this count for prefill/decode caches so
    # they shard over 'model' (removes S-shard merges + resharding
    # copies in decode; exact GQA transform) — §Perf
    kv_repeat_to: int = 0
    # pad q heads (group-aware) + replicate kv so heads divide the model
    # axis; attention then shards 16-way instead of running replicated
    # (exact: padded q rows are zero and sliced off) — §Perf
    attn_pad_heads: bool = False
    moment_dtype: str = "float32"    # adamw moments: float32|bfloat16|int8

    # -- shapes this arch must skip (assignment rules) ------------------------
    skip_shapes: Tuple[str, ...] = ()

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, hd = self.d_model, self.head_dim
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d
        if self.family in ("hybrid", "ssm"):
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            ssm = (d * (2 * d_in + 2 * self.ssm_state + nh)   # in_proj
                   + d_in * d                                  # out_proj
                   + 3 * self.ssm_conv_width * d_in + 2 * nh)
            if self.family == "ssm":
                block = ssm + 2 * d  # norms; xlstm approximated as ssm-ish
            else:
                block = ssm + 2 * d
            n_attn = (self.hybrid_shared_attn_blocks * (attn + 3 * d * self.d_ff)
                      if self.family == "hybrid" else 0)
            total = self.n_layers * block + n_attn
        elif self.is_moe:
            ffn = 3 * d * self.moe_d_ff
            shared = self.n_shared_experts * 3 * d * self.moe_d_ff
            router = d * self.moe_experts
            block = attn + self.moe_experts * ffn + shared + router + 2 * d
            total = self.n_layers * block
        else:
            block = attn + 3 * d * self.d_ff + 2 * d
            total = self.n_layers * block
            if self.is_encdec:
                total += self.enc_layers * (2 * attn + 3 * d * self.d_ff
                                            + 3 * d)
        total += self.vocab * d * (1 if self.embedding == "bbit_hash"
                                   else 2)
        if self.embedding == "bbit_hash":
            total += self.hash_k * (1 << self.hash_b) * d
        return int(total)

    def n_active_params(self) -> int:
        """Active (per-token) params — MoE counts top_k experts only."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        dense = self.n_params() - self.n_layers * (
            self.moe_experts * 3 * d * self.moe_d_ff)
        return int(dense + self.n_layers
                   * self.moe_top_k * 3 * d * self.moe_d_ff)


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    # populate the registry lazily
    import repro.configs.archs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    import repro.configs.archs  # noqa: F401
    return dict(_REGISTRY)
