"""zamba2-7b — hybrid Mamba2 + shared attention blocks
[arXiv:2411.15242; unverified].

81L d_model=3584 32H (GQA kv=32 → MHA) d_ff=14336 vocab=32000,
ssm_state=64.  The layer stack is Mamba2 blocks with a *shared*
attention(+MLP) block applied every ``hybrid_attn_every`` layers,
alternating between ``hybrid_shared_attn_blocks`` weight sets — the
Zamba weight-sharing scheme.  Sub-quadratic backbone → runs long_500k.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_head=112,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    hybrid_attn_every=6,
    hybrid_shared_attn_blocks=2,
    rope_variant="standard",
))
