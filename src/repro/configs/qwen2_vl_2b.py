"""qwen2-vl-2b — VLM backbone, M-RoPE [arXiv:2409.12191; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.  The vision
tower is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings (B, n_patches, d_model) merged into the
token stream; M-RoPE carries (t, h, w) position ids.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_head=128,
    d_ff=8960,
    vocab=151936,
    rope_variant="mrope",
    mrope_sections=(16, 24, 24),
    frontend="vision_stub",
    frontend_len=256,            # patches per image
    skip_shapes=("long_500k",),
))
