"""seamless-m4t-large-v2 — enc-dec, multimodal [arXiv:2308.11596; hf].

24L d_model=1024 16H (kv=16 → MHA) d_ff=8192 vocab=256206.  Interpreted
as 24 encoder + 24 decoder layers (the real model's w2v-BERT speech
encoder + NLLB text decoder; DESIGN.md §5).  The audio frontend is a
STUB: ``input_specs()`` supplies precomputed frame embeddings
(B, frames, d_model) to the encoder.  Decode shapes exercise the text
decoder with cached cross-attention.  256k vocab → the prime target for
the paper's b-bit hashed-embedding compression (§Perf).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,                 # decoder layers
    enc_layers=24,               # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=8192,
    vocab=256206,
    rope_variant="none",         # learned/sinusoidal in the original;
                                 # positions handled by the enc/dec stubs
    frontend="audio_stub",
    frontend_len=1024,           # encoder frames per utterance
    skip_shapes=("long_500k",),
))
