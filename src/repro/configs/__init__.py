"""Config system: ArchConfig schema, registry, assigned architectures."""
from repro.configs.base import ArchConfig, register, get_config, list_configs

__all__ = ["ArchConfig", "register", "get_config", "list_configs"]
