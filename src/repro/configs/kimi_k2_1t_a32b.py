"""kimi-k2-1t-a32b — trillion-param MoE [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840,
MoE 384 experts top-8 (+1 shared expert).  Assignment table values are
authoritative (the real Kimi K2 uses MLA; the assignment specifies GQA
kv=8, which we follow).  int8 AdamW moments are required to fit 1.04T
params in 512×16 GB (DESIGN.md §6).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=112,
    d_ff=2048,
    vocab=163840,
    moe_experts=384,
    moe_top_k=8,
    moe_d_ff=2048,
    n_shared_experts=1,
    rope_variant="standard",
    rope_theta=50000.0,
    moment_dtype="int8",
    skip_shapes=("long_500k",),   # full attention — O(S²) at 500k
))
