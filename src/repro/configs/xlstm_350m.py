"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304.  Every ``slstm_every``-th
block is a (recurrent) sLSTM; the rest are (chunk-parallel) mLSTM.
Recurrent state is O(1) in sequence length → runs long_500k.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_head=256,
    d_ff=0,                  # xLSTM blocks have no separate FFN
    vocab=50304,
    ssm_expand=2,
    slstm_every=6,
    rope_variant="none",
))
