"""Imports every architecture config module to populate the registry."""
from repro.configs import (  # noqa: F401
    kimi_k2_1t_a32b,
    granite_moe_3b_a800m,
    deepseek_67b,
    chatglm3_6b,
    yi_9b,
    internlm2_1_8b,
    zamba2_7b,
    xlstm_350m,
    qwen2_vl_2b,
    seamless_m4t_large_v2,
)

ALL_ARCHS = (
    "kimi-k2-1t-a32b",
    "granite-moe-3b-a800m",
    "deepseek-67b",
    "chatglm3-6b",
    "yi-9b",
    "internlm2-1.8b",
    "zamba2-7b",
    "xlstm-350m",
    "qwen2-vl-2b",
    "seamless-m4t-large-v2",
)
