"""The paper's own configuration: b-bit hashed linear model on the
expanded rcv1 (200 GB → n·b·k bits).

Production settings follow the paper's best-performing regime
(k=500, b=16 — Figures 1-4) over the D≈2^30 expanded feature space,
trained with LR (Eq. 9) or L2-SVM (Eq. 8) at LIBLINEAR C∈[1e-3,1e2].
The multi-pod dry-run lowers this model's train_step on the production
mesh with the (k, 2^b, C) weight table sharded over 'model' (TP over k)
and the batch over ('pod','data').
"""
import dataclasses

from repro.models.linear import BBitLinearConfig


@dataclasses.dataclass(frozen=True)
class PaperConfig:
    name: str = "rcv1-bbit"
    k: int = 500
    b: int = 16
    n_classes: int = 2
    loss: str = "logistic"       # or 'squared_hinge' (Eq. 8)
    C: float = 1.0
    ambient_dim: int = 1 << 30   # expanded rcv1: D ≈ 1.01e9
    global_batch: int = 65536    # examples per distributed step
    hash_family: str = "multiply_shift"
    scheme: str = "minwise"      # see configs.rcv1_oph for the OPH twin
    seed: int = 0

    def linear_config(self) -> BBitLinearConfig:
        return BBitLinearConfig(k=self.k, b=self.b,
                                n_classes=self.n_classes)


CONFIG = PaperConfig()
