"""Expanded-rcv1 with One Permutation Hashing preprocessing.

Same learning problem as ``rcv1_bbit`` (b-bit hashed linear model over
the D≈2^30 expanded feature space, LR/L2-SVM at LIBLINEAR C) but the
one-time hashing pass uses densified OPH (arXiv:1208.1259 +
arXiv:1406.4784): ONE hash evaluation per nonzero instead of k, cutting
the paper's dominant preprocessing cost (Table 2) by ~k× while keeping
the same n·b·k-bit storage and statistically equivalent codes.

k=256 (power of two — OPH bins are lane-aligned top-bit ranges) at b=8
sits on the paper's accuracy plateau (Figures 1-4 show b=8, k≥200
within ~0.1% of the b=16 ceiling) at a quarter of the storage of the
k=500/b=16 minwise config.
"""
import dataclasses
from typing import Optional

from repro.models.linear import BBitLinearConfig


@dataclasses.dataclass(frozen=True)
class OPHPaperConfig:
    name: str = "rcv1-oph"
    scheme: str = "oph"          # densified; 'oph_zero' for zero-coding
    k: int = 256                 # bins — must be a power of two
    b: int = 8
    n_classes: int = 2
    loss: str = "logistic"       # or 'squared_hinge' (Eq. 8)
    C: float = 1.0
    ambient_dim: int = 1 << 30   # expanded rcv1: D ≈ 1.01e9
    global_batch: int = 65536    # examples per distributed step
    seed: int = 0
    # streaming preprocessing (PR 2): rows per fused-encode chunk and
    # shards per hashed dataset — peak preprocessing memory is
    # O(pipeline depth · chunk + one shard), never the (n, k) matrix
    preprocess_chunk: int = 4096
    preprocess_shards: int = 16
    # streaming training (PR 3): train.streaming.fit_streaming over the
    # v3 shards — one-pass SGD + Polyak tail averaging, packed bytes to
    # the device, shard-boundary checkpoints.  avg_start_frac opens the
    # tail-averaging window after that fraction of planned steps.
    stream_batch: int = 1024
    stream_lr: float = 1e-2
    stream_epochs: int = 1       # one pass — the VW-online comparison
    avg_start_frac: float = 0.5
    ckpt_every_shards: int = 4
    # overlapped hot path (PR 4): async producer→queue→device pipeline
    # depth (0 = inline; any depth is bit-identical) and the data-
    # parallel world size (None = single device; N shards the epoch's
    # shard groups over N devices with psum_mean gradient all-reduce)
    stream_prefetch: int = 2
    stream_data_parallel: Optional[int] = None
    # serving hot path (PR 5): fused encode→score engine — per-bucket
    # micro-batching lanes (nnz pad widths), replica count over a 1-D
    # mesh, and the batcher's dispatch/resolve overlap depth
    serve_max_batch: int = 64
    serve_max_wait_ms: float = 2.0
    serve_replicas: int = 1
    serve_nnz_buckets: tuple = (128, 512, 2048, 8192, 32768)
    serve_pipeline_depth: int = 2
    # network serving tier (PR 6): the asyncio HTTP front end over the
    # engine — bind address, graceful-drain budget, rolling stats
    # window, adaptive-bucket cadence (0 = static lane grid), and the
    # in-flight row budget (None = derive from the engine's real
    # pipeline concurrency, AdmissionController.for_engine)
    serve_host: str = "127.0.0.1"
    serve_port: int = 8077
    serve_drain_timeout_s: float = 30.0
    serve_stats_window: int = 4096
    serve_adapt_every: int = 0
    serve_inflight_limit: Optional[int] = None
    # fault tolerance (PR 7): the supervised restart loop around
    # fit_streaming — restart budget + capped exponential backoff
    # between restarts — the checkpoint ring depth (fallback set when
    # the newest checkpoint is torn/corrupt), and elastic resume
    # (fold the logical data-parallel world onto however many devices
    # are alive; power-of-two counts stay bit-identical)
    ft_max_restarts: int = 3
    ft_backoff_base_s: float = 1.0
    ft_backoff_cap_s: float = 60.0
    ft_ckpt_keep_last: int = 3
    ft_elastic: bool = True
    # multi-host gang training (PR 10): process count for
    # ``train.supervisor.run_multiprocess_supervised`` (1 = classic
    # single-process), the coordinated-checkpoint barrier budget, and
    # the optional error-feedback gradient compression over the gang's
    # all-reduce (None = exact fp32; 8 = int8 blockwise-absmax, 1 =
    # sign+scale — the paper's b-bit storage argument applied to the
    # gradient wire format)
    stream_procs: int = 1
    ft_barrier_timeout_s: float = 120.0
    stream_grad_compress: Optional[int] = None
    # cost-model dispatch (PR 8): a measured perf profile consumed by
    # launch/train.py, launch/serve.py and the benchmarks — "calibrate
    # once, run fast" (launch/calibrate.py writes it; a missing or
    # wrong-device file silently degrades to the static heuristics) —
    # and the calibration pass's own knobs
    profile_path: str = "artifacts/perf/profile.json"
    calibrate_budget_s: float = 60.0
    calibrate_trials: int = 3
    calibrate_max_batch: int = 64
    calibrate_nnz_buckets: tuple = (128, 512, 2048)
    # duplicate-traffic dedup cache + LSH retrieval (PR 9): the serving
    # engine's band-keyed score cache (serving/dedup.py — probe on
    # dedup_probe_bands band keys, guard on exact packed-code equality,
    # invalidated per WeightSet swap) and the banded retrieval index's
    # geometry.  rows_per_band=4 at b=8 gives 32-bit band keys, 64
    # bands at k=256 — collision probability ~R^4, steep enough that
    # near-duplicates probe the same bucket while unrelated docs don't.
    dedup_cache: bool = True
    dedup_entries: int = 65536
    dedup_rows_per_band: int = 4
    dedup_probe_bands: int = 4
    retrieval_rows_per_band: int = 4
    retrieval_top_k: int = 10

    def linear_config(self) -> BBitLinearConfig:
        return BBitLinearConfig(k=self.k, b=self.b,
                                n_classes=self.n_classes)

    def stream_kwargs(self, **overrides) -> dict:
        """Keyword arguments for ``train.streaming.fit_streaming`` at
        this config's paper scale; pass overrides for scaled-down runs
        (examples/benchmarks shrink batch/epochs, keep the averaging
        and checkpoint cadence)."""
        kw = dict(epochs=self.stream_epochs, batch_size=self.stream_batch,
                  lr=self.stream_lr, avg_start_frac=self.avg_start_frac,
                  ckpt_every_shards=self.ckpt_every_shards,
                  prefetch=self.stream_prefetch,
                  data_parallel=self.stream_data_parallel,
                  elastic=self.ft_elastic,
                  ckpt_keep_last=self.ft_ckpt_keep_last,
                  grad_compress=self.stream_grad_compress,
                  ckpt_barrier_timeout_s=self.ft_barrier_timeout_s)
        kw.update(overrides)
        return kw

    def restart_policy(self):
        """The ``train.supervisor.RestartPolicy`` for production runs
        at this config (``launch/train.py --supervise``): a restart
        budget with capped exponential backoff — long waits, because a
        real crash usually means the box needs a moment."""
        from repro.ft.retry import BackoffPolicy
        from repro.train.supervisor import RestartPolicy
        return RestartPolicy(
            max_restarts=self.ft_max_restarts,
            backoff=BackoffPolicy(base_s=self.ft_backoff_base_s,
                                  factor=2.0,
                                  cap_s=self.ft_backoff_cap_s,
                                  jitter_frac=0.1, seed=self.seed))

    def serve_kwargs(self, **overrides) -> dict:
        """Keyword arguments for ``serving.HashedClassifierEngine`` at
        this config's scale; examples/benches override buckets and
        batch size for scaled-down corpora."""
        kw = dict(scheme=self.scheme, max_batch=self.serve_max_batch,
                  max_wait_ms=self.serve_max_wait_ms,
                  replicas=self.serve_replicas,
                  nnz_buckets=self.serve_nnz_buckets,
                  pipeline_depth=self.serve_pipeline_depth,
                  stats_window=self.serve_stats_window,
                  adapt_every=self.serve_adapt_every)
        kw.update(overrides)
        return kw

    def dedup_kwargs(self, **overrides) -> dict:
        """Keyword arguments enabling the engine's duplicate-traffic
        score cache — merge into ``serve_kwargs()``'s dict (kept
        separate so batching knobs and cache knobs stay independently
        overridable)."""
        kw = dict(dedup_cache=self.dedup_cache,
                  dedup_entries=self.dedup_entries,
                  dedup_rows_per_band=self.dedup_rows_per_band,
                  dedup_probe_bands=self.dedup_probe_bands)
        kw.update(overrides)
        return kw

    def calibrate_kwargs(self, **overrides) -> dict:
        """Keyword arguments for ``perf.calibrate`` at this config's
        scale — the one-shot microbenchmark pass behind
        ``launch/calibrate.py``."""
        kw = dict(k=self.k, b_values=(self.b,), schemes=(self.scheme,),
                  max_batch=self.calibrate_max_batch,
                  nnz_buckets=self.calibrate_nnz_buckets,
                  trials=self.calibrate_trials,
                  budget_s=self.calibrate_budget_s, seed=self.seed)
        kw.update(overrides)
        return kw

    def http_kwargs(self, **overrides) -> dict:
        """Keyword arguments for ``serving.ScoreServer`` — the HTTP
        front end around an engine built with ``serve_kwargs``."""
        kw = dict(host=self.serve_host, port=self.serve_port,
                  drain_timeout_s=self.serve_drain_timeout_s)
        kw.update(overrides)
        return kw


CONFIG = OPHPaperConfig()
